"""Hierarchical span tracing with a near-zero disabled fast path.

A :class:`Span` is one timed region — name, start (``perf_counter``),
duration, logical track (``tid``), free-form ``attrs`` — and spans nest
per thread: :meth:`Tracer.span` pushes onto a thread-local stack, so a
``stage.assign`` span opened inside a ``sweep_point`` span records the
parent's depth and the Chrome-trace exporter renders the hierarchy
from the B/E nesting.

The **module-level** entry points are what instrumented code calls:

* :func:`trace_span` — ``with trace_span("stage.merge", k_prime=4):``
  returns a shared no-op context manager when no tracer is active
  (one global load + ``is None`` test: scheduling hot paths pay
  nothing when tracing is off);
* :func:`current_tracer` / :func:`span_attr` — attach attributes
  (e.g. counter deltas) to the innermost open span;
* :func:`activate` — install a tracer for a ``with`` region (the
  scheduler and service loops activate around one run).

Tracing is **provably inert**: spans only read clocks and append to a
list, never feed back into control flow — makespans and service
traces are bit-identical with tracing on or off (asserted by
``tests/test_obs.py``).

Worker processes of the parallel k' sweep install a fresh tracer per
sweep-point task and ship their finished spans back picklably inside
the ``SweepPoint``; the parent splices them into its own tracer, so
one Chrome trace shows worker tracks next to the main process.
"""
from __future__ import annotations

import os
import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass, field

__all__ = [
    "Span",
    "Tracer",
    "activate",
    "current_tracer",
    "span_attr",
    "trace_span",
    "tracing_active",
]


@dataclass
class Span:
    """One finished timed region (picklable; ``to_dict`` for JSONL)."""

    name: str
    ts: float                 # perf_counter at entry (seconds)
    dur: float                # seconds
    tid: str                  # logical track, e.g. "main" / "worker-123"
    depth: int = 0            # nesting depth at entry (0 = root)
    attrs: dict = field(default_factory=dict)

    def to_dict(self) -> dict:
        return {"name": self.name, "ts": self.ts, "dur": self.dur,
                "tid": self.tid, "depth": self.depth,
                "attrs": dict(self.attrs)}


class _OpenSpan:
    __slots__ = ("name", "t0", "attrs")

    def __init__(self, name: str, t0: float, attrs: dict) -> None:
        self.name = name
        self.t0 = t0
        self.attrs = attrs


class Tracer:
    """Collects spans; one per run (scheduler, service, or user-owned).

    ``probe_spans`` opts into the innermost span level — one span per
    incremental-engine probe (:mod:`repro.core.incremental`).  Off by
    default even when tracing: probes fire tens of thousands of times
    per sweep and the per-span cost would break the ≤10 % enabled
    overhead budget; flip it on for a microscope view of one run.
    """

    def __init__(self, *, probe_spans: bool = False,
                 tid: str | None = None) -> None:
        self.spans: list[Span] = []
        self.probe_spans = probe_spans
        self._default_tid = tid
        self._local = threading.local()
        self._lock = threading.Lock()

    # ------------------------------------------------------------ #
    def _tid(self) -> str:
        if self._default_tid is not None:
            return self._default_tid
        t = threading.current_thread()
        if t is threading.main_thread():
            return f"pid-{os.getpid()}"
        return f"pid-{os.getpid()}/{t.name}"

    def _stack(self) -> list[_OpenSpan]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    @contextmanager
    def span(self, name: str, **attrs):
        stack = self._stack()
        depth = len(stack)
        open_span = _OpenSpan(name, time.perf_counter(), attrs)
        stack.append(open_span)
        try:
            yield open_span
        finally:
            t1 = time.perf_counter()
            stack.pop()
            sp = Span(name=name, ts=open_span.t0,
                      dur=t1 - open_span.t0, tid=self._tid(),
                      depth=depth, attrs=open_span.attrs)
            with self._lock:
                self.spans.append(sp)

    def attr(self, **kv) -> None:
        """Attach attributes to the innermost open span (no-op when no
        span is open)."""
        stack = self._stack()
        if stack:
            stack[-1].attrs.update(kv)

    def extend(self, spans) -> None:
        """Splice finished spans in (worker shipments; already closed,
        their ``tid`` identifies the worker track)."""
        with self._lock:
            self.spans.extend(spans)

    # ------------------------------------------------------------ #
    def by_duration(self, n: int | None = None) -> list[Span]:
        """Spans slowest-first (the ``tools/trace_view.py`` table)."""
        out = sorted(self.spans, key=lambda s: -s.dur)
        return out if n is None else out[:n]


# ------------------------------------------------------------------ #
# the active-tracer slot and the disabled fast path
# ------------------------------------------------------------------ #
_ACTIVE: Tracer | None = None


class _DiscardDict(dict):
    """A write-discarding dict: attribute updates on the null span go
    nowhere (and allocate nothing) when tracing is off."""

    __slots__ = ()

    def __setitem__(self, k, v) -> None:
        pass

    def update(self, *a, **kw) -> None:
        pass


_DISCARD = _DiscardDict()


class _NullSpan:
    """Shared no-op context manager — the disabled fast path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    attrs: dict = _DISCARD


_NULL_SPAN = _NullSpan()


def current_tracer() -> Tracer | None:
    """The active tracer, or ``None`` when tracing is off."""
    return _ACTIVE


def tracing_active() -> bool:
    return _ACTIVE is not None


def trace_span(name: str, **attrs):
    """Open a span on the active tracer (shared no-op when inactive)."""
    tr = _ACTIVE
    if tr is None:
        return _NULL_SPAN
    return tr.span(name, **attrs)


def span_attr(**kv) -> None:
    """Attach attributes to the active tracer's innermost open span."""
    tr = _ACTIVE
    if tr is not None:
        tr.attr(**kv)


@contextmanager
def activate(tracer: Tracer | None):
    """Install ``tracer`` as the active tracer for the ``with`` body.

    ``activate(None)`` is a no-op passthrough, so callers can write
    ``with activate(tracer if enabled else None):`` unconditionally —
    an enclosing activation (e.g. the service loop's tracer around a
    scheduler run) stays in effect.  Exit restores the previous
    tracer.
    """
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = tracer if tracer is not None else prev
    try:
        yield tracer
    finally:
        _ACTIVE = prev


@contextmanager
def activate_exclusive(tracer: Tracer | None):
    """Install ``tracer`` *overriding* any enclosing activation —
    ``None`` forcibly disables tracing for the body.  Pool workers use
    this so a fork-inherited parent tracer never collects worker spans
    that could not ship back."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = tracer
    try:
        yield tracer
    finally:
        _ACTIVE = prev
