"""Elastic rescale: losing nodes = a new (smaller, possibly more
heterogeneous) platform.

The framework's response has two halves:

1. **State**: checkpoints are saved unsharded (gathered); restoring
   onto the surviving mesh is just ``load_pytree`` with the new mesh's
   shardings (``repro.checkpoint``).
2. **Placement**: the paper's scheduler re-plans.  A node failure is
   *exactly* the situation DagHetPart was designed for — a platform
   whose memory/speed profile changed — so we rerun ``autoshard.plan``
   on ``platform.without(failed)`` and compare the new stage map.

``rescale_plan`` returns both the new plan and a migration summary
(which stages moved), which a deployment would turn into data moves.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.autoshard import PartitionPlan, plan
from repro.core.platform import Platform

__all__ = ["rescale_plan", "RescaleReport"]


@dataclass
class RescaleReport:
    old_plan: PartitionPlan
    new_plan: PartitionPlan | None
    failed: set[int]
    moved_tasks: int
    est_step_before_s: float
    est_step_after_s: float | None

    @property
    def feasible(self) -> bool:
        return self.new_plan is not None


def rescale_plan(cfg, shape, platform: Platform, failed: set[int],
                 old_plan: PartitionPlan | None = None,
                 **plan_kw) -> RescaleReport:
    """Re-plan placement after losing processors ``failed``."""
    if old_plan is None:
        old_plan = plan(cfg, shape, platform, **plan_kw)
        if old_plan is None:
            raise RuntimeError("infeasible even before failure")
    survivors = platform.without(failed)
    new_plan = plan(cfg, shape, survivors, **plan_kw)
    moved = 0
    if new_plan is not None:
        for task, st in new_plan.stage_of_task.items():
            old_st = old_plan.stage_of_task.get(task)
            if old_st is None or old_st != st:
                moved += 1
    return RescaleReport(
        old_plan=old_plan,
        new_plan=new_plan,
        failed=failed,
        moved_tasks=moved,
        est_step_before_s=old_plan.est_step_s,
        est_step_after_s=new_plan.est_step_s if new_plan else None,
    )
