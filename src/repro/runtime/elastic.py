"""Elastic rescale: losing nodes = a one-event scenario.

The framework's response has two halves:

1. **State**: checkpoints are saved unsharded (gathered); restoring
   onto the surviving mesh is just ``load_pytree`` with the new mesh's
   shardings (``repro.checkpoint``).
2. **Placement**: the paper's scheduler re-plans.  A node failure is
   *exactly* the situation DagHetPart was designed for — a platform
   whose memory/speed profile changed — so :func:`rescale_plan` lowers
   the model to its workflow DAG, wraps the failure in a
   :class:`repro.scenario.ProcFailure` timeline and runs it through
   :func:`repro.scenario.run_scenario`.

Migration note
--------------
``rescale_plan`` used to raise ``RuntimeError`` when even the
pre-failure fleet could not hold the model and returned plans built on
the deprecated ``MappingResult | None`` contract.  It now *always*
returns a :class:`RescaleReport` backed by a
:class:`~repro.scenario.TimelineReport`: infeasibility (before or
after the failure) is a structured
:class:`~repro.core.scheduler.Infeasibility` on
``report.infeasibility``, the stitched timeline (Gantt, migration log,
per-segment reports) rides on ``report.timeline``, and ``at`` /
``policy`` select *when* the failure strikes and *how* to replan
(``"full-replan"`` — the old cold-replan behaviour and still the
default — or ``"pinned-warm-start"`` to keep completed/in-flight work
in place).
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.autoshard import PartitionPlan, _distill, default_microbatches
from repro.core.modelgraph import build_model_graph
from repro.core.platform import Platform
from repro.core.scheduler import Infeasibility, SchedulerConfig
from repro.scenario import ProcFailure, Scenario, TimelineReport, run_scenario

__all__ = ["rescale_plan", "RescaleReport"]


@dataclass
class RescaleReport:
    """Outcome of re-planning placement around a processor failure.

    ``old_plan`` / ``new_plan`` are distilled
    :class:`~repro.core.autoshard.PartitionPlan` views of the pre- and
    post-failure mappings (``None`` where that side was infeasible);
    ``timeline`` the full scenario record.  ``moved_tasks`` counts
    migrated + displaced tasks from the timeline's migration log (the
    data moves a deployment would execute).
    """

    old_plan: PartitionPlan | None
    new_plan: PartitionPlan | None
    failed: set[int]
    moved_tasks: int
    est_step_before_s: float | None
    est_step_after_s: float | None
    timeline: TimelineReport = field(repr=False, default=None)

    @property
    def feasible(self) -> bool:
        return self.new_plan is not None

    @property
    def infeasibility(self) -> Infeasibility | None:
        return self.timeline.infeasibility if self.timeline else None


def rescale_plan(cfg, shape, platform: Platform, failed: set[int],
                 *, at: float = 0.0, policy: str = "full-replan",
                 algo: str = "dag_het_part", kprime="auto",
                 workers: int = 1,
                 microbatches: int | None = None) -> RescaleReport:
    """Re-plan placement after losing processors ``failed``.

    ``at`` is the failure time on the simulated execution clock
    (``0.0``: nothing ran yet — the old cold-rescale semantics);
    ``policy`` is any :mod:`repro.scenario` replan policy name.  Never
    raises on infeasibility — read ``report.infeasibility``.
    """
    if microbatches is None:
        microbatches = default_microbatches(shape)
    wf, info = build_model_graph(cfg, shape, microbatches=microbatches)
    scenario = Scenario(wf, platform,
                        [ProcFailure(time=at, procs=frozenset(failed))],
                        name=f"{cfg.name}/{shape.name}-rescale")
    timeline = run_scenario(
        scenario, policy,
        config=SchedulerConfig(algorithm=algo, kprime=kprime,
                               workers=workers))

    old_plan = new_plan = None
    if timeline.segments:
        seg0 = timeline.segments[0]
        old_plan = _distill(cfg, shape, seg0.mapping,
                            seg0.mapping.quotient.wf, info,
                            seg0.platform, algo)
        old_plan.report = seg0.report
        last = timeline.segments[-1]
        if timeline.feasible and last.index > 0:
            info_res = {i: info[g] for i, g in enumerate(last.task_ids)}
            new_plan = _distill(cfg, shape, last.mapping,
                                last.mapping.quotient.wf, info_res,
                                last.platform, algo)
            new_plan.report = last.report
        elif timeline.feasible:
            # failure never fired (e.g. ``at`` past completion)
            new_plan = old_plan
    moved = sum(m.moved_tasks + m.displaced_tasks
                for m in timeline.migrations)
    return RescaleReport(
        old_plan=old_plan,
        new_plan=new_plan,
        failed=set(failed),
        moved_tasks=moved,
        est_step_before_s=(old_plan.est_step_s if old_plan else None),
        est_step_after_s=(new_plan.est_step_s if new_plan else None),
        timeline=timeline,
    )
