from .elastic import RescaleReport, rescale_plan
from .fault import (
    FailureInjector,
    SimulatedFault,
    StragglerMonitor,
    run_with_restarts,
)
from .pipeline import pipeline_apply, stack_stage_params
from .train_loop import Trainer, TrainerConfig

__all__ = [
    "RescaleReport", "rescale_plan",
    "FailureInjector", "SimulatedFault", "StragglerMonitor",
    "run_with_restarts",
    "pipeline_apply", "stack_stage_params",
    "Trainer", "TrainerConfig",
]
