"""Fault-tolerance primitives.

* :class:`FailureInjector` — deterministic fault injection for tests
  (raise at a given step, or with a given probability),
* :func:`run_with_restarts` — supervisor loop: run, catch, restore from
  the latest checkpoint, resume; gives up after ``max_restarts``,
* :class:`StragglerMonitor` — per-step timing stats; flags outliers and
  exposes a *degraded fleet view* (slow hosts as slower processors) so
  the paper's scheduler can re-plan around stragglers instead of just
  waiting on them.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field

__all__ = ["FailureInjector", "run_with_restarts", "StragglerMonitor",
           "SimulatedFault"]


class SimulatedFault(RuntimeError):
    """Raised by the injector — stands in for a lost host/preemption."""


@dataclass
class FailureInjector:
    fail_at_steps: tuple[int, ...] = ()
    max_failures: int = 1
    _count: int = 0

    def check(self, step: int) -> None:
        if self._count < self.max_failures and step in self.fail_at_steps:
            self._count += 1
            raise SimulatedFault(f"injected fault at step {step}")


def run_with_restarts(make_state, run, *, max_restarts: int = 3,
                      on_restart=None):
    """Supervisor: ``state = make_state()`` then ``run(state)``.

    ``run`` must be resumable — it reloads progress from checkpoints via
    ``make_state``.  Returns ``(result, n_restarts)``.
    """
    restarts = 0
    while True:
        state = make_state()
        try:
            return run(state), restarts
        except SimulatedFault:
            restarts += 1
            if restarts > max_restarts:
                raise
            if on_restart is not None:
                on_restart(restarts)


@dataclass
class StragglerMonitor:
    """Rolling per-step wall-time statistics with outlier detection.

    In a multi-host deployment each host reports its step time; a host
    whose times exceed ``threshold`` × median is flagged.  The monitor
    then exposes a degraded :class:`~repro.core.platform.Platform` view
    — the hook that lets DagHetPart re-plan placement around a slow
    host (straggler mitigation by re-mapping, not just waiting).
    """

    threshold: float = 1.5
    window: int = 32
    times: dict[int, list[float]] = field(default_factory=dict)

    def record(self, host: int, seconds: float) -> None:
        buf = self.times.setdefault(host, [])
        buf.append(seconds)
        if len(buf) > self.window:
            del buf[0]

    def _medians(self) -> dict[int, float]:
        meds = {}
        for host, buf in self.times.items():
            s = sorted(buf)
            meds[host] = s[(len(s) - 1) // 2]  # lower median
        return meds

    def stragglers(self) -> list[int]:
        return sorted(self.slowdown_factors())

    def slowdown_factors(self) -> dict[int, float]:
        """Per-straggler speed factor ``overall_median / host_median``
        (< 1/threshold by construction): the fraction of nominal speed
        a straggling host is actually delivering."""
        meds = self._medians()
        if len(meds) < 2:
            return {}
        overall = sorted(meds.values())[(len(meds) - 1) // 2]
        return {
            h: overall / m
            for h, m in meds.items()
            if m > self.threshold * overall
        }

    def speed_events(self, platform, host_of_proc, *, at: float = 0.0):
        """The measured slowdowns as :class:`repro.scenario.SpeedChange`
        events at time ``at`` — the handoff from monitoring to
        mid-trace replanning (``Scenario(wf, platform, events)``)."""
        from repro.scenario import SpeedChange

        factors = self.slowdown_factors()
        return [
            SpeedChange(time=at, proc=j, factor=factors[host_of_proc(j)])
            for j in range(platform.k)
            if host_of_proc(j) in factors
        ]

    def degraded_platform(self, platform, host_of_proc):
        """Platform with straggler processors' speeds scaled by their
        measured slowdown — input for scheduler re-planning.

        Built by applying :meth:`speed_events`, so it is exactly the
        platform a :class:`repro.scenario.Scenario` carrying those
        events would replan on (per-link bandwidth overrides included —
        the old hand-rolled rebuild dropped them).
        """
        events = self.speed_events(platform, host_of_proc)
        if not events:
            return platform
        out = platform
        for ev in events:
            out, _ = ev.apply(out)
        out.name = platform.name + "-degraded"
        return out


class StepTimer:
    def __init__(self) -> None:
        self.t0 = time.perf_counter()

    def lap(self) -> float:
        t = time.perf_counter()
        dt = t - self.t0
        self.t0 = t
        return dt
