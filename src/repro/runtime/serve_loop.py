"""Batched serving loop: request queue → slot-based continuous batching.

Production shape in miniature: a fixed pool of ``slots`` (the batch
dimension of the jitted decode step), requests admitted the moment a
slot frees up, per-slot cache cursors (vectorized positions through
the decode path), greedy decode until EOS/max-tokens, slot recycled.
One jitted step serves the whole pool every iteration regardless of
request boundaries — the invariant continuous batching exists to
maintain.

Restriction: attention-cache architectures only (Mamba/RWKV slots
would need per-slot state resets — documented future work).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import LM

__all__ = ["Request", "ServeLoop"]


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # [P] int32
    max_new_tokens: int = 16
    eos_id: int = -1                    # -1: never stops early
    out: list = field(default_factory=list)
    done: bool = False


class ServeLoop:
    """Continuous-batching server over a reduced-config model."""

    def __init__(self, model: LM, params, *, slots: int = 4,
                 max_len: int = 64) -> None:
        if any(s.kind != "attn" for s in model.specs):
            raise ValueError(
                "continuous batching requires attention caches "
                "(stateful SSM/RWKV slots need per-slot state resets)")
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.cache = model.init_cache(slots, max_len, dtype=jnp.float32)
        self.queue: deque[Request] = deque()
        self.active: list[Request | None] = [None] * slots
        # per-slot cursor: index the next token will be written at
        self.pos = np.zeros(slots, np.int32)
        self.tokens = np.zeros((slots, 1), np.int32)

        self._step = jax.jit(
            lambda params, cache, tokens, pos:
            model.decode_step(params, cache, tokens, pos))

    # ------------------------------------------------------------------ #
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                req = self.queue.popleft()
                self.active[s] = req
                self.pos[s] = 0
                self.tokens[s, 0] = req.prompt[0]

    def _advance_slot(self, s: int, logits: np.ndarray) -> None:
        req = self.active[s]
        if req is None:
            self.pos[s] = 0           # idle slots rewrite position 0
            return
        p = int(self.pos[s])
        plen = len(req.prompt)
        if p + 1 < plen:                       # still prefilling
            self.tokens[s, 0] = req.prompt[p + 1]
        else:                                  # generating
            tok = int(np.argmax(logits))
            req.out.append(tok)
            self.tokens[s, 0] = tok
            if (len(req.out) >= req.max_new_tokens
                    or tok == req.eos_id
                    or p + 2 >= self.max_len):
                req.done = True
                self.active[s] = None
                self.pos[s] = 0
                return
        self.pos[s] = p + 1

    def run(self, max_steps: int = 10_000) -> list[Request]:
        """Serve until queue + slots drain; returns finished requests."""
        finished: list[Request] = []
        steps = 0
        while (any(r is not None for r in self.active)
               or self.queue) and steps < max_steps:
            self._admit()
            logits, self.cache = self._step(
                self.params, self.cache, jnp.asarray(self.tokens),
                jnp.asarray(self.pos))
            logits_np = np.asarray(logits[:, -1])
            for s in range(self.slots):
                before = self.active[s]
                self._advance_slot(s, logits_np[s])
                if before is not None and before.done:
                    finished.append(before)
            steps += 1
        return finished
