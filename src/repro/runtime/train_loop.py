"""The training loop: data prefetch, jitted steps, periodic async
checkpoints, fault injection hooks, straggler monitoring.

Runs for real on CPU with reduced configs (examples/tests) and lowers
unchanged for the production meshes (the dry-run lowers the same
``build_train_step`` bundle).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from pathlib import Path

import jax
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs.base import ModelConfig, ShapeConfig
from repro.data import DataConfig, Prefetcher, SyntheticTokens
from repro.models import LM
from repro.optim import AdamWConfig, adamw_init, adamw_update, warmup_cosine

from .fault import FailureInjector, StepTimer, StragglerMonitor

__all__ = ["TrainerConfig", "Trainer"]


@dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 25
    log_every: int = 10
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    async_ckpt: bool = True
    seed: int = 0
    opt: AdamWConfig = field(default_factory=AdamWConfig)


class Trainer:
    """Single-host trainer (multi-host = same loop + sharded feeding)."""

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig,
                 tcfg: TrainerConfig, *, mesh=None,
                 param_dtype=None, attn_chunk: int = 64,
                 injector: FailureInjector | None = None) -> None:
        import jax.numpy as jnp
        self.cfg = cfg
        self.shape = shape
        self.tcfg = tcfg
        self.injector = injector
        self.monitor = StragglerMonitor()
        self.model = LM(
            cfg,
            param_dtype=param_dtype or jnp.float32,
            attn_chunk=attn_chunk,
            max_seq=shape.seq_len + 8,
            remat="none",
        )
        self.data = SyntheticTokens(DataConfig(
            vocab_size=cfg.vocab_size,
            seq_len=shape.seq_len,
            global_batch=shape.global_batch,
            seed=tcfg.seed,
            frontend_tokens=cfg.frontend_tokens,
            frontend_dim=cfg.frontend_dim,
        ))
        self.ckpt = Checkpointer(tcfg.ckpt_dir, keep=tcfg.keep,
                                 async_save=tcfg.async_ckpt)

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(self.model.loss)(params, batch)
            lr_scale = warmup_cosine(opt_state["step"], warmup=10,
                                     total=max(tcfg.steps, 20))
            params, opt_state, metrics = adamw_update(
                tcfg.opt, params, grads, opt_state, lr_scale)
            metrics["loss"] = loss
            return params, opt_state, metrics

        self.step_fn = jax.jit(train_step, donate_argnums=(0, 1))

    # ------------------------------------------------------------------ #
    def init_or_restore(self):
        params = self.model.init(self.tcfg.seed)
        opt_state = adamw_init(params)
        start = 0
        latest = self.ckpt.latest_step()
        if latest is not None:
            state, meta = self.ckpt.restore(
                {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            start = int(meta["step"]) + 1
        return params, opt_state, start

    def run(self, steps: int | None = None) -> dict:
        """Train; returns metrics history. Resumes from checkpoints."""
        steps = steps or self.tcfg.steps
        params, opt_state, start = self.init_or_restore()
        it = (self.data.batch_at(s) for s in range(start, steps))
        prefetch = Prefetcher(it)
        history = {"loss": [], "step": [], "restarted_at": start}
        timer = StepTimer()
        try:
            for step in range(start, steps):
                if self.injector is not None:
                    self.injector.check(step)
                batch = {k: jax.numpy.asarray(v)
                         for k, v in prefetch.get().items()}
                params, opt_state, metrics = self.step_fn(
                    params, opt_state, batch)
                dt = timer.lap()
                self.monitor.record(0, dt)
                loss = float(metrics["loss"])
                if not np.isfinite(loss):
                    raise FloatingPointError(f"loss diverged at {step}")
                history["loss"].append(loss)
                history["step"].append(step)
                if (step + 1) % self.tcfg.ckpt_every == 0 or \
                        step == steps - 1:
                    self.ckpt.save(step, {"params": params,
                                          "opt": opt_state})
        finally:
            prefetch.close()
        self.ckpt.wait()
        return history
