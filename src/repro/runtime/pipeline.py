"""Pipeline-parallel execution: GPipe microbatch schedule over a
"stage" mesh axis via shard_map + collective_permute.

The scheduler (autoshard) decides *which* blocks form stages; this
module is the runtime that executes a stage-partitioned model:

* stage parameters are stacked ``[n_stages, ...]`` and sharded over the
  "stage" axis (one stage's weights per device group),
* microbatches flow through a rotating buffer: at step t, stage s
  processes microbatch ``t − s`` (when valid) and the buffer is
  ``collective_permute``d one stage forward,
* total steps = µ + S − 1 (fill + drain); outputs accumulate on the
  last stage,
* ``jax.grad`` through the runner yields the reverse (backward)
  pipeline automatically — the transpose of collective_permute is the
  reverse permute, so the GPipe backward schedule falls out of
  autodiff.

This is the PP building block the dry-run meshes don't exercise (they
use DP/FSDP/TP axes); tests run it on 4 host devices in a subprocess.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["pipeline_apply", "stack_stage_params"]


def stack_stage_params(per_stage: list) -> dict:
    """Stack a list of per-stage param pytrees along a leading axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per_stage)


def pipeline_apply(stage_fn, stage_params, x, *, mesh: Mesh,
                   axis: str = "stage", microbatches: int | None = None):
    """Run ``x`` through a pipeline of stages.

    Args:
      stage_fn: ``(params_slice, x_mb) -> x_mb`` — one stage's compute.
      stage_params: pytree stacked ``[S, ...]``, sharded over ``axis``.
      x: ``[B, ...]`` global input batch (replicated).
      mesh: mesh containing the ``axis`` of size S.
      microbatches: µ (defaults to S — the minimum for full utilization).

    Returns ``[B, ...]`` outputs (replicated).
    """
    n_stages = mesh.shape[axis]
    mu = microbatches or n_stages
    b = x.shape[0]
    if b % mu:
        raise ValueError(f"batch {b} not divisible by {mu} microbatches")
    mb = b // mu
    xs = x.reshape((mu, mb) + x.shape[1:])

    fwd_perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def per_stage(params_local, xs_local):
        # params_local: [1, ...] (this stage's slice); xs_local: [µ, mb, ...]
        params_local = jax.tree.map(lambda t: t[0], params_local)
        stage_id = jax.lax.axis_index(axis)
        steps = mu + n_stages - 1
        # pvary: the carry becomes device-varying after the first
        # ppermute, so its initial value must be typed as varying too
        # (jax < 0.5 has no explicit varying types: identity there)
        pvary = getattr(jax.lax, "pvary", lambda x, axes: x)
        buf = pvary(jnp.zeros_like(xs_local[0]), (axis,))
        out = pvary(jnp.zeros_like(xs_local), (axis,))

        def step(carry, t):
            buf, out = carry
            # stage 0 injects microbatch t (while t < µ)
            inject = jnp.where(t < mu, t, 0)
            buf = jnp.where(stage_id == 0,
                            xs_local[inject], buf)
            y = stage_fn(params_local, buf)
            # microbatch index this stage just produced
            m = t - stage_id
            valid = (m >= 0) & (m < mu)
            out = jnp.where(
                (stage_id == n_stages - 1) & valid,
                jax.lax.dynamic_update_slice_in_dim(
                    out, y[None], jnp.clip(m, 0, mu - 1), axis=0),
                out)
            # rotate stage s -> s+1
            buf = jax.lax.ppermute(y, axis, fwd_perm)
            return (buf, out), None

        (buf, out), _ = jax.lax.scan(step, (buf, out),
                                     jnp.arange(steps))
        # out is only populated on the last stage; emit per-stage and
        # let the caller slice (the vma type system can't see that a
        # broadcast ppermute would make it replicated)
        return out[None]

    from jax.experimental.shard_map import shard_map

    spec_params = jax.tree.map(lambda _: P(axis), stage_params)
    result = shard_map(
        per_stage,
        mesh=mesh,
        in_specs=(spec_params, P()),
        out_specs=P(axis),
    )(stage_params, xs)
    # [S, µ, mb, ...] — the last stage's buffer holds the outputs
    return result[-1].reshape((b,) + x.shape[1:])
