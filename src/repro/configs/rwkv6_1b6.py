"""RWKV6 "Finch" 1.6B — attention-free, data-dependent decay
[arXiv:2404.05892]."""
from dataclasses import replace

from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,          # wkv heads of size 64
    n_kv_heads=32,
    head_dim=64,
    d_ff=7168,
    vocab_size=65536,
    attention_free=True,
    source="arXiv:2404.05892",
)


def smoke_config() -> ModelConfig:
    return replace(
        CONFIG,
        n_layers=2,
        d_model=64,
        n_heads=2,
        n_kv_heads=2,
        head_dim=32,
        d_ff=128,
        vocab_size=256,
    )
