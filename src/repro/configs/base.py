"""Architecture & shape configuration system.

Every assigned architecture ships one module defining ``CONFIG``
(exact published dims) and ``smoke_config()`` (a reduced same-family
variant for CPU tests).  Shapes are global (same four for the LM pool).

Sizes here drive three consumers:

* ``repro.models`` — the actual JAX modules,
* ``repro.core.modelgraph`` — the analytic workflow DAG fed to the
  paper's scheduler,
* ``repro.launch`` — dry-run input specs and sharding rules.
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass, field, replace

__all__ = [
    "ModelConfig",
    "ShapeConfig",
    "SHAPES",
    "ARCH_IDS",
    "get_config",
    "get_smoke_config",
    "shape_by_name",
]


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    # --- MoE ---------------------------------------------------------- #
    n_experts: int = 0
    experts_per_token: int = 0
    moe_layer_period: int = 1       # every p-th layer is MoE (jamba: 2)
    # --- hybrid (attention/SSM interleave) ----------------------------- #
    attn_layer_period: int = 0      # 0: all attn; p: layers p-1, 2p-1, ... attn
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    # --- attention-free (rwkv) ----------------------------------------- #
    attention_free: bool = False
    # --- frontends / enc-dec ------------------------------------------- #
    n_encoder_layers: int = 0       # >0: encoder-decoder
    cross_attn_period: int = 0      # vlm: every p-th layer cross-attends
    frontend_tokens: int = 0        # stub frontend: #precomputed embeddings
    frontend_dim: int = 0           # stub frontend: embedding dim
    # --- misc ----------------------------------------------------------- #
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    sliding_window: int = 0         # 0: full attention
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_encdec(self) -> bool:
        return self.n_encoder_layers > 0

    def layer_kind(self, i: int) -> str:
        """Kind of decoder layer ``i``: attn | mamba | rwkv."""
        if self.attention_free:
            return "rwkv"
        if self.attn_layer_period > 0:
            return (
                "attn"
                if (i % self.attn_layer_period) == self.attn_layer_period - 1
                else "mamba"
            )
        return "attn"

    def layer_is_moe(self, i: int) -> bool:
        if not self.is_moe:
            return False
        return (i % self.moe_layer_period) == self.moe_layer_period - 1

    def layer_cross_attends(self, i: int) -> bool:
        if self.cross_attn_period <= 0:
            return False
        return (i % self.cross_attn_period) == self.cross_attn_period - 1

    # ------------------------------------------------------------------ #
    # parameter counts (used by roofline + scheduler weights)
    # ------------------------------------------------------------------ #
    def attn_params(self) -> int:
        d, hd = self.d_model, self.hd
        q = d * self.n_heads * hd
        kv = 2 * d * self.n_kv_heads * hd
        o = self.n_heads * hd * d
        bias = (self.n_heads + 2 * self.n_kv_heads) * hd if self.qkv_bias else 0
        return q + kv + o + bias

    def mamba_params(self) -> int:
        d = self.d_model
        d_in = self.mamba_expand * d
        # in_proj (x,z), conv, x_proj (dt,B,C), dt_proj, out_proj, A, D
        return (
            d * 2 * d_in
            + d_in * self.mamba_d_conv
            + d_in * (self.mamba_d_state * 2 + d_in // 16)
            + (d_in // 16) * d_in
            + d_in * d
            + d_in * self.mamba_d_state
            + d_in
        )

    def rwkv_params(self) -> int:
        d = self.d_model
        # time-mix: r,k,v,g,o projections + data-dependent decay lora
        return 5 * d * d + 4 * d * 64

    def mlp_params(self, d_ff: int | None = None) -> int:
        f = d_ff if d_ff is not None else self.d_ff
        return 3 * self.d_model * f  # SwiGLU: gate, up, down

    def layer_params(self, i: int) -> int:
        kind = self.layer_kind(i)
        if kind == "attn":
            mix = self.attn_params()
        elif kind == "mamba":
            mix = self.mamba_params()
        else:
            mix = self.rwkv_params()
        if self.layer_is_moe(i):
            ffn = self.n_experts * self.mlp_params() + self.d_model * self.n_experts
        else:
            ffn = self.mlp_params()
        if self.layer_cross_attends(i):
            mix += self.attn_params()
        return mix + ffn + 2 * self.d_model  # + norms

    def total_params(self) -> int:
        p = self.vocab_size * self.d_model  # embed
        if not self.tie_embeddings:
            p += self.vocab_size * self.d_model  # lm head
        for i in range(self.n_layers):
            p += self.layer_params(i)
        if self.is_encdec:
            enc = replace(
                self, n_experts=0, cross_attn_period=0,
                n_encoder_layers=0, attention_free=False,
                attn_layer_period=0,
            )
            for i in range(self.n_encoder_layers):
                p += enc.layer_params(i)
        return p

    def active_params(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if not self.is_moe:
            return self.total_params()
        p = self.total_params()
        for i in range(self.n_layers):
            if self.layer_is_moe(i):
                p -= (self.n_experts - self.experts_per_token) * self.mlp_params()
        return p


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = (
    "mixtral_8x7b",
    "olmoe_1b_7b",
    "minitron_4b",
    "granite_8b",
    "qwen25_32b",
    "llama3_8b",
    "rwkv6_1b6",
    "jamba_15_large",
    "llama32_vision_90b",
    "seamless_m4t_v2",
)

_ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}
_ALIASES.update({
    "mixtral-8x7b": "mixtral_8x7b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "minitron-4b": "minitron_4b",
    "granite-8b": "granite_8b",
    "qwen2.5-32b": "qwen25_32b",
    "llama3-8b": "llama3_8b",
    "rwkv6-1.6b": "rwkv6_1b6",
    "jamba-1.5-large-398b": "jamba_15_large",
    "llama-3.2-vision-90b": "llama32_vision_90b",
    "seamless-m4t-large-v2": "seamless_m4t_v2",
})


def _module(arch: str):
    key = _ALIASES.get(arch, arch)
    if key not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{key}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).smoke_config()


def shape_by_name(name: str) -> ShapeConfig:
    return SHAPES[name]
