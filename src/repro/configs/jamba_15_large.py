"""Jamba-1.5-Large (398B total / 94B active) — hybrid Mamba+attention
with MoE, attention every 8th layer, MoE every 2nd [arXiv:2403.19887]."""
from dataclasses import replace

from .base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    n_experts=16,
    experts_per_token=2,
    moe_layer_period=2,      # every other layer's FFN is MoE
    attn_layer_period=8,     # 1:7 attention:mamba interleave
    mamba_d_state=16,
    source="arXiv:2403.19887",
)


def smoke_config() -> ModelConfig:
    return replace(
        CONFIG,
        n_layers=4,            # keeps one attn + three mamba layers
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        n_experts=4,
        experts_per_token=2,
        attn_layer_period=4,
    )
