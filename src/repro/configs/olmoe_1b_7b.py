"""OLMoE-1B-7B — 64-expert top-8 MoE [arXiv:2409.02060; hf]."""
from dataclasses import replace

from .base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,          # per-expert FFN width
    vocab_size=50304,
    n_experts=64,
    experts_per_token=8,
    source="arXiv:2409.02060",
)


def smoke_config() -> ModelConfig:
    return replace(
        CONFIG,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=96,
        vocab_size=256,
        n_experts=8,
        experts_per_token=2,
    )
