"""Qwen2.5-32B — dense decoder, GQA, QKV bias [hf:Qwen/Qwen2.5-*]."""
from dataclasses import replace

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=27648,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1e6,
    source="hf:Qwen/Qwen2.5-0.5B",
)


def smoke_config() -> ModelConfig:
    return replace(
        CONFIG,
        n_layers=2,
        d_model=80,
        n_heads=5,
        n_kv_heads=1,
        d_ff=192,
        vocab_size=512,
    )
