"""Llama-3.2-Vision-90B backbone — cross-attention image layers
[hf:meta-llama/Llama-3.2-11B-Vision].  The vision frontend is a STUB:
``input_specs`` provides precomputed patch embeddings (per brief)."""
from dataclasses import replace

from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    cross_attn_period=5,       # every 5th layer cross-attends to vision
    frontend_tokens=1601 * 4,  # 4 tiles of 1601 patch embeddings
    frontend_dim=8192,
    rope_theta=500_000.0,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)


def smoke_config() -> ModelConfig:
    return replace(
        CONFIG,
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        cross_attn_period=2,
        frontend_tokens=16,
        frontend_dim=64,
    )
