"""Granite-8B-Code — llama-arch dense decoder [arXiv:2405.04324; hf]."""
from dataclasses import replace

from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=49152,
    tie_embeddings=True,
    source="arXiv:2405.04324",
)


def smoke_config() -> ModelConfig:
    return replace(
        CONFIG,
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=160,
        vocab_size=384,
    )
