"""SeamlessM4T-large-v2 backbone — encoder-decoder, multimodal
[arXiv:2308.11596].  The speech frontend is a STUB: ``input_specs``
provides precomputed frame embeddings (per brief)."""
from dataclasses import replace

from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,             # decoder layers
    n_encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    # published vocab is 256 206; padded to a multiple of 256 (standard
    # deployment practice) so the embedding/logits shard over the
    # 16-way "model" axis — unpadded, the 256 206×1024 embedding plus
    # its f32 optimizer state replicate (8.4 GiB/chip) and the loss
    # chunks blow temp memory (measured; see EXPERIMENTS.md §Perf)
    vocab_size=256256,
    frontend_tokens=4096,    # precomputed speech frames (stub frontend)
    frontend_dim=1024,
    source="arXiv:2308.11596",
)


def smoke_config() -> ModelConfig:
    return replace(
        CONFIG,
        n_layers=2,
        n_encoder_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=384,
        frontend_tokens=24,
        frontend_dim=64,
    )
