"""Llama-3-8B — dense decoder, GQA, 128k vocab [arXiv:2407.21783]."""
from dataclasses import replace

from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=500_000.0,
    source="arXiv:2407.21783",
)


def smoke_config() -> ModelConfig:
    return replace(
        CONFIG,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=512,
    )
