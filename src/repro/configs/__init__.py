"""Architecture configs — one module per assigned architecture."""
from .base import (
    ARCH_IDS,
    SHAPES,
    ModelConfig,
    ShapeConfig,
    get_config,
    get_smoke_config,
    shape_by_name,
)

__all__ = [
    "ARCH_IDS",
    "SHAPES",
    "ModelConfig",
    "ShapeConfig",
    "get_config",
    "get_smoke_config",
    "shape_by_name",
]
