"""Minitron-4B — pruned Nemotron dense decoder [arXiv:2407.14679; hf]."""
from dataclasses import replace

from .base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=9216,
    vocab_size=256000,
    source="arXiv:2407.14679",
)


def smoke_config() -> ModelConfig:
    return replace(
        CONFIG,
        n_layers=2,
        d_model=96,
        n_heads=6,
        n_kv_heads=2,
        d_ff=192,
        vocab_size=512,
    )
