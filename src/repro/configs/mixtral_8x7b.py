"""Mixtral-8x7B — sparse MoE decoder [arXiv:2401.04088; hf]."""
from dataclasses import replace

from .base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    n_experts=8,
    experts_per_token=2,
    rope_theta=1e6,
    sliding_window=4096,
    source="arXiv:2401.04088",
)


def smoke_config() -> ModelConfig:
    return replace(
        CONFIG,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        n_experts=4,
        experts_per_token=2,
        sliding_window=0,
    )
