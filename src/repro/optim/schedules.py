"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["warmup_cosine", "constant"]


def constant(step, **_):
    return jnp.ones_like(step, dtype=jnp.float32)


def warmup_cosine(step, *, warmup: int = 100, total: int = 10_000,
                  min_frac: float = 0.1):
    """Scale factor in [min_frac, 1]: linear warmup then cosine decay."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(warmup, 1), 1.0)
    progress = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = min_frac + (1.0 - min_frac) * 0.5 * (1.0 + jnp.cos(jnp.pi * progress))
    return warm * jnp.where(step < warmup, 1.0, cos)
