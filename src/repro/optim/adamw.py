"""AdamW with f32 master weights over (possibly bf16) params.

Self-contained (no optax offline): init / update are pure pytree maps,
which also makes ZeRO-style sharding trivial — the optimizer state
pytree mirrors the param pytree, so the launcher applies the same
PartitionSpec rules plus an extra data-axis sharding for ZeRO-1.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm",
           "clip_by_global_norm"]


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # bf16 Adam moments (Gopher/PaLM-style) save 8 bytes/param — the
    # difference between fitting and not fitting 100B+ models on
    # 16 GiB chips; update math stays f32.
    moment_dtype: str = "float32"


def adamw_init(params, moment_dtype: str = "float32") -> dict:
    mdt = jnp.dtype(moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        # explicit copy: when params are already f32, astype would alias
        # the same buffer and break donation (same buffer donated twice)
        "master": jax.tree.map(
            lambda p: jnp.array(p, dtype=jnp.float32), params),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(cfg: AdamWConfig, params, grads, state, lr_scale=1.0):
    """One AdamW step; returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t
    lr = cfg.lr * lr_scale

    def upd(g, m, v, master):
        g = g.astype(jnp.float32)
        mdt = m.dtype
        m = cfg.b1 * m.astype(jnp.float32) + (1.0 - cfg.b1) * g
        v = cfg.b2 * v.astype(jnp.float32) + (1.0 - cfg.b2) * g * g
        update = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        master = master - lr * (update + cfg.weight_decay * master)
        return m.astype(mdt), v.astype(mdt), master

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    flat_w = jax.tree.leaves(state["master"])
    new_m, new_v, new_w = [], [], []
    for g, m, v, w in zip(flat_g, flat_m, flat_v, flat_w):
        m2, v2, w2 = upd(g, m, v, w)
        new_m.append(m2)
        new_v.append(v2)
        new_w.append(w2)
    param_dtypes = [p.dtype for p in jax.tree.leaves(params)]
    new_params = treedef.unflatten(
        [w.astype(dt) for w, dt in zip(new_w, param_dtypes)])
    new_state = {
        "step": step,
        "m": treedef.unflatten(new_m),
        "v": treedef.unflatten(new_v),
        "master": treedef.unflatten(new_w),
    }
    return new_params, new_state, {"grad_norm": gnorm}
