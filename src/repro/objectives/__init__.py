"""repro.objectives — reliability- and energy-aware schedule pricing.

The paper optimizes one axis: makespan under memory constraints.  This
subsystem prices a mapped schedule on two more (ROADMAP item 4;
grounding: Tekawade & Banerjee, *Makespan and Energy-Aware Scheduling
under Reliability Constraint*, and Benoit, Rehn-Sonigo & Robert,
*Multi-criteria scheduling of pipeline workflows* — see PAPERS.md):

* **Reliability** — with per-processor exponential failure rates
  (:attr:`Platform.failure_rates <repro.core.platform.Platform>`), a
  block computing for ``d`` seconds on processor ``j`` survives with
  probability ``exp(-λ_j · d)``.  Failures are independent, so the
  whole schedule's success probability is
  ``exp(-Σ_v λ_proc(v) · exposure_v)`` and
  :func:`schedule_reliability` reports it together with the
  *reliability-weighted makespan* ``makespan / success_prob`` — the
  expected completion cost when a failed run must be repeated.
* **Energy** — with per-processor :class:`ProcPower
  <repro.core.platform.ProcPower>` models (``static + dynamic·s^α``),
  :func:`schedule_energy` integrates per-block dynamic energy
  (``dynamic · w · (f·s)^(α-1)`` at DVFS scale ``f``) plus per-proc
  static energy (``static × horizon``), and :func:`energy_plan`
  *minimizes* it under a reliability floor by choosing a per-block
  speed scale from a DVFS ladder: slowing a block saves dynamic energy
  (α > 1) but lengthens its failure exposure, so the greedy raises the
  speeds with the best exposure-reduction-per-joule until the floor is
  met — or reports the floor unreachable (``None``; the scheduler's
  ``energy`` stage turns that into a structured
  ``Infeasibility(stage="objective")``).

Both axes plug into the scheduler as pipeline stages (algorithms
``"reliability"`` / ``"energy"``, registered via ``register_pipeline``
and swept over k' in parallel like any other pipeline); the stages are
**bit-inert** when the platform carries no failure/power model, so the
makespan pipeline's output is unchanged on model-free platforms.
:func:`plan_reliability` / :func:`plan_energy` select the sweep attempt
that wins on the *objective* (not makespan) from the per-point metric
observations, exactly as :func:`repro.throughput.plan_throughput` does
for rate.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

from repro.core.platform import Platform, ProcPower

__all__ = [
    "EnergyReport",
    "EnergyResult",
    "ReliabilityReport",
    "ReliabilityResult",
    "block_exposures",
    "energy_from_sim",
    "energy_plan",
    "plan_energy",
    "plan_reliability",
    "schedule_energy",
    "schedule_reliability",
]


# ---------------------------------------------------------------------- #
# reliability
# ---------------------------------------------------------------------- #
@dataclass
class ReliabilityReport:
    """Success probability of one mapped schedule.

    ``exposure[v]`` is block ``v``'s compute duration (its at-risk
    window on its processor), ``hazard`` the summed ``λ · exposure``
    over all blocks, ``success_prob = exp(-hazard)`` ∈ (0, 1], and
    ``weighted_makespan = makespan / success_prob`` — the expected
    completion cost when a failed schedule is re-run from scratch.
    ``proc_hazard`` splits the hazard by processor *name* (names are
    stable across failures; indices are not).
    """

    success_prob: float
    hazard: float
    makespan: float
    weighted_makespan: float
    exposure: dict[int, float] = field(default_factory=dict)
    proc_hazard: dict[str, float] = field(default_factory=dict)

    def to_dict(self) -> dict:
        return {
            "success_prob": self.success_prob,
            "hazard": self.hazard,
            "makespan": self.makespan,
            "weighted_makespan": self.weighted_makespan,
            "exposure": [[v, x] for v, x in sorted(self.exposure.items())],
            "proc_hazard": dict(sorted(self.proc_hazard.items())),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "ReliabilityReport":
        return cls(
            success_prob=d["success_prob"],
            hazard=d["hazard"],
            makespan=d["makespan"],
            weighted_makespan=d["weighted_makespan"],
            exposure={v: x for v, x in d.get("exposure", [])},
            proc_hazard=dict(d.get("proc_hazard", {})),
        )


def block_exposures(mapping, platform: Platform,
                    speed_scale: dict[int, float] | None = None,
                    ) -> dict[int, float]:
    """Per-block compute durations ``w_v / (f_v · s_proc(v))``.

    ``speed_scale`` optionally maps a block id to its DVFS scale factor
    (default 1.0 = nominal speed).  This is the exposure-time input of
    both the reliability and the energy accounting.
    """
    q = mapping.quotient
    out: dict[int, float] = {}
    for v in sorted(q.members):
        f = speed_scale.get(v, 1.0) if speed_scale else 1.0
        out[v] = q.weight[v] / (f * platform.procs[q.proc[v]].speed)
    return out


def schedule_reliability(mapping, platform: Platform | None = None,
                         *, speed_scale: dict[int, float] | None = None,
                         makespan: float | None = None,
                         ) -> ReliabilityReport:
    """Price a mapping's success probability from per-block exposure
    time × its processor's failure rate (independent exponential
    failures).  Without a failure model every λ is 0 and the report is
    the trivial ``success_prob=1.0``.
    """
    res = getattr(mapping, "best", mapping)
    platform = platform if platform is not None else res.platform
    q = res.quotient
    exposure = block_exposures(res, platform, speed_scale)
    hazard = 0.0
    proc_hazard: dict[str, float] = {}
    for v, dur in exposure.items():
        j = q.proc[v]
        lam = platform.failure_rate(j)
        if lam <= 0:
            continue
        h = lam * dur
        hazard += h
        name = platform.procs[j].name
        proc_hazard[name] = proc_hazard.get(name, 0.0) + h
    prob = math.exp(-hazard)
    ms = float(makespan if makespan is not None else res.makespan)
    # exp(-hazard) underflows to exactly 0.0 around hazard ~ 745; the
    # weighted makespan is then "never finishes", not a ZeroDivisionError
    weighted = ms / prob if prob > 0.0 else math.inf
    return ReliabilityReport(
        success_prob=prob, hazard=hazard, makespan=ms,
        weighted_makespan=weighted, exposure=exposure,
        proc_hazard=proc_hazard,
    )


# ---------------------------------------------------------------------- #
# energy
# ---------------------------------------------------------------------- #
@dataclass
class EnergyReport:
    """Energy of one mapped schedule, decomposed so that

    ``total == sum(per_block_dynamic.values())
             + sum(per_proc_static.values())``

    holds *by construction* (the property the accounting tests pin).
    ``per_block_dynamic[v]`` integrates the dynamic power of block
    ``v``'s compute interval at its chosen DVFS scale
    (``dynamic · w_v · (f_v·s_j)^(α-1)``); ``per_proc_static`` is keyed
    by processor *name* and integrates static power over ``horizon`` —
    the nominal makespan stretched by the worst slowdown
    ``max(1/f_v)`` when DVFS scaling is in force.  ``reliability`` is
    the success probability *under the chosen speeds* (slower blocks
    are exposed longer); ``reliability_floor`` echoes the constraint
    :func:`energy_plan` enforced (``None`` for unconstrained pricing).
    """

    total: float
    dynamic: float
    static: float
    horizon: float
    per_block_dynamic: dict[int, float] = field(default_factory=dict)
    per_proc_static: dict[str, float] = field(default_factory=dict)
    speed_of_block: dict[int, float] = field(default_factory=dict)
    reliability: float = 1.0
    reliability_floor: float | None = None

    def to_dict(self) -> dict:
        return {
            "total": self.total,
            "dynamic": self.dynamic,
            "static": self.static,
            "horizon": self.horizon,
            "per_block_dynamic": [[v, e] for v, e in
                                  sorted(self.per_block_dynamic.items())],
            "per_proc_static": dict(sorted(self.per_proc_static.items())),
            "speed_of_block": [[v, f] for v, f in
                               sorted(self.speed_of_block.items())],
            "reliability": self.reliability,
            "reliability_floor": self.reliability_floor,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "EnergyReport":
        return cls(
            total=d["total"], dynamic=d["dynamic"], static=d["static"],
            horizon=d["horizon"],
            per_block_dynamic={v: e for v, e
                               in d.get("per_block_dynamic", [])},
            per_proc_static=dict(d.get("per_proc_static", {})),
            speed_of_block={v: f for v, f in d.get("speed_of_block", [])},
            reliability=d.get("reliability", 1.0),
            reliability_floor=d.get("reliability_floor"),
        )


def _dynamic_energy(weight: float, speed: float, f: float,
                    pw: ProcPower) -> float:
    """Dynamic energy of one block: power ``dynamic·(f·s)^α`` times
    duration ``w/(f·s)`` — the closed form ``dynamic·w·(f·s)^(α-1)``."""
    return pw.dynamic * weight * (f * speed) ** (pw.alpha - 1.0)


def schedule_energy(mapping, platform: Platform | None = None,
                    *, speed_of_block: dict[int, float] | None = None,
                    reliability_floor: float | None = None,
                    ) -> EnergyReport:
    """Integrate a mapping's energy under the platform's power model.

    Per-block dynamic integrals at the given DVFS scales (default
    nominal) plus per-processor static integrals over the schedule
    horizon; processors without a :class:`ProcPower` entry contribute
    nothing.  The decomposition invariant of :class:`EnergyReport`
    holds exactly.
    """
    res = getattr(mapping, "best", mapping)
    platform = platform if platform is not None else res.platform
    q = res.quotient
    scales = dict(speed_of_block or {})
    per_block: dict[int, float] = {}
    for v in sorted(q.members):
        j = q.proc[v]
        pw = platform.proc_power(j)
        f = scales.get(v, 1.0)
        per_block[v] = (_dynamic_energy(q.weight[v],
                                        platform.procs[j].speed, f, pw)
                        if pw is not None else 0.0)
    stretch = max((1.0 / f for f in scales.values()), default=1.0)
    horizon = float(res.makespan) * max(stretch, 1.0)
    per_proc: dict[str, float] = {}
    for j, pw in sorted(platform.power.items()):
        per_proc[platform.procs[j].name] = pw.static * horizon
    dynamic = sum(per_block.values())
    static = sum(per_proc.values())
    rel = schedule_reliability(res, platform, speed_scale=scales)
    return EnergyReport(
        total=dynamic + static, dynamic=dynamic, static=static,
        horizon=horizon, per_block_dynamic=per_block,
        per_proc_static=per_proc,
        speed_of_block={v: scales.get(v, 1.0) for v in per_block},
        reliability=rel.success_prob,
        reliability_floor=reliability_floor,
    )


def energy_plan(mapping, platform: Platform | None = None,
                *, reliability_floor: float | None = None,
                speed_levels=(1.0,),
                ) -> EnergyReport | None:
    """Minimize energy under a reliability floor via per-block DVFS.

    ``speed_levels`` is the ladder of scale factors (each in (0, 1];
    1.0 — nominal speed — is always available).  Every block starts at
    the *lowest* level (minimum dynamic energy, since α > 1 makes
    dynamic energy increase with speed); while the schedule's success
    probability is below ``reliability_floor``, the greedy raises the
    block/level step with the best hazard reduction per joule.  Returns
    ``None`` when even all-nominal speeds miss the floor — the caller
    (the ``energy`` scheduler stage) reports that as a structured
    ``Infeasibility(stage="objective")``, never an exception.
    """
    res = getattr(mapping, "best", mapping)
    platform = platform if platform is not None else res.platform
    q = res.quotient
    levels = sorted({float(f) for f in speed_levels} | {1.0})
    for f in levels:
        if not 0 < f <= 1.0:
            raise ValueError(
                f"DVFS speed levels must be in (0, 1], got {f!r}")

    vids = sorted(q.members)
    lam = {v: platform.failure_rate(q.proc[v]) for v in vids}
    spd = {v: platform.procs[q.proc[v]].speed for v in vids}
    pw = {v: platform.proc_power(q.proc[v]) for v in vids}

    def hazard_at(v: int, f: float) -> float:
        return lam[v] * q.weight[v] / (f * spd[v])

    def dyn_at(v: int, f: float) -> float:
        p = pw[v]
        return (_dynamic_energy(q.weight[v], spd[v], f, p)
                if p is not None else 0.0)

    lvl = {v: 0 for v in vids}
    top = len(levels) - 1

    def success() -> float:
        return math.exp(-sum(hazard_at(v, levels[lvl[v]]) for v in vids))

    if reliability_floor is not None:
        # feasibility first: the floor must be reachable at nominal
        if math.exp(-sum(hazard_at(v, 1.0) for v in vids)) \
                < reliability_floor:
            return None
        while success() < reliability_floor:
            best = None  # (score, -dh, v): max hazard drop per joule
            for v in vids:
                i = lvl[v]
                if i >= top:
                    continue
                f0, f1 = levels[i], levels[i + 1]
                dh = hazard_at(v, f0) - hazard_at(v, f1)
                de = dyn_at(v, f1) - dyn_at(v, f0)
                score = dh / de if de > 0 else math.inf
                key = (score, dh, -v)
                if best is None or key > best[0]:
                    best = (key, v)
            if best is None:   # pragma: no cover — nominal check above
                return None
            lvl[best[1]] += 1

    scales = {v: levels[lvl[v]] for v in vids}
    return schedule_energy(res, platform, speed_of_block=scales,
                           reliability_floor=reliability_floor)


def energy_from_sim(sim, platform: Platform) -> dict:
    """Energy/exposure accounting from the engine's per-proc busy
    integrals (:attr:`SimReport.procs
    <repro.sim.report.SimReport>`\\ 's ``busy_s``) — the simulation-side
    counterpart of :func:`schedule_energy` at nominal speeds.

    Returns a plain dict: per-proc-name ``dynamic`` (busy integral ×
    ``dynamic·s^α``), ``static`` (horizon × static), ``exposure``
    (λ-weighted busy integrals), plus ``total`` / ``success_prob``.
    This is what :func:`repro.sim.simulate` attaches as
    ``SimReport.energy`` when the platform carries a model.
    """
    dynamic: dict[str, float] = {}
    static: dict[str, float] = {}
    exposure: dict[str, float] = {}
    hazard = 0.0
    busy = {p.proc: p.busy_s for p in sim.procs}
    horizon = sim.horizon
    for j in range(platform.k):
        name = platform.procs[j].name
        b = busy.get(j, 0.0)
        pw = platform.proc_power(j)
        if pw is not None:
            dynamic[name] = (pw.dynamic
                             * platform.procs[j].speed ** pw.alpha * b)
            static[name] = pw.static * horizon
        lam = platform.failure_rate(j)
        if lam > 0:
            exposure[name] = b
            hazard += lam * b
    return {
        "dynamic": dynamic,
        "static": static,
        "exposure": exposure,
        "total": sum(dynamic.values()) + sum(static.values()),
        "hazard": hazard,
        "success_prob": math.exp(-hazard),
    }


# ---------------------------------------------------------------------- #
# objective-winning sweep selection (mirrors plan_throughput)
# ---------------------------------------------------------------------- #
@dataclass
class ReliabilityResult:
    """What :func:`plan_reliability` returns — never ``None``.

    ``report`` is the full k'-sweep ``ScheduleReport``; ``best`` /
    ``reliability`` the weighted-makespan-minimizing mapping and its
    :class:`ReliabilityReport` (``None`` when no attempt was feasible).
    """

    report: object
    best: object | None
    reliability: ReliabilityReport | None
    k_prime: int | None

    @property
    def feasible(self) -> bool:
        return self.best is not None


@dataclass
class EnergyResult:
    """What :func:`plan_energy` returns — never ``None``."""

    report: object
    best: object | None
    energy: EnergyReport | None
    k_prime: int | None

    @property
    def feasible(self) -> bool:
        return self.best is not None


def _point_observation(point, name: str) -> float | None:
    """The attempt's single objective observation from its metrics
    block (the stage observes exactly once per attempt, so the
    histogram's ``sum`` is the value — same contract as
    ``plan_throughput``)."""
    h = point.metrics.get("histograms", {}).get(name)
    if not h or not h.get("count"):
        return None
    return float(h["sum"])


def _plan_objective(wf, platform, algorithm: str, metric: str,
                    objective_options: dict | None, config, overrides):
    """Run ``algorithm``'s pipeline over the k' sweep and re-materialize
    the attempt minimizing ``metric`` (ties: smaller makespan, then
    earlier sweep position)."""
    from repro.core.scheduler import Scheduler, SchedulerConfig

    cfg = config if config is not None else SchedulerConfig()
    run_overrides = {"algorithm": algorithm, **overrides}
    if objective_options is not None:
        merged = dict(cfg.objective_options or {})
        merged.update(objective_options)
        run_overrides["objective_options"] = merged
    report = Scheduler(cfg, **run_overrides).schedule(wf, platform)
    if report.best is None:
        return report, None, None

    best_kp = None
    best_val = math.inf
    best_ms = math.inf
    for p in report.sweep:
        if not p.feasible:
            continue
        val = _point_observation(p, metric)
        if val is None:
            continue
        if val < best_val or (val == best_val and p.makespan < best_ms):
            best_kp, best_val, best_ms = p.k_prime, val, p.makespan
    best = report.best
    if best_kp is not None and best_kp != best.extras.get("k_prime"):
        # the objective winner lost the makespan reduction: re-run the
        # single winning k' (stages are deterministic)
        rerun = Scheduler(cfg, **{**run_overrides, "kprime": [best_kp],
                                  "workers": 1}).schedule(wf, platform)
        if rerun.best is not None:
            best = rerun.best
    return report, best, best.extras.get("k_prime")


def plan_reliability(wf, platform: Platform, *, config=None,
                     **overrides) -> ReliabilityResult:
    """Plan ``wf`` minimizing the reliability-weighted makespan.

    Runs the registered ``reliability`` pipeline across the k' sweep
    (``config`` / ``overrides`` are ``SchedulerConfig`` material), then
    picks the attempt with the smallest ``makespan / success_prob``
    from the per-point ``objective_rel_weighted_ms`` observations — a
    finer partition may lose on raw makespan yet win weighted, when it
    keeps exposure off failure-prone processors.  Without a failure
    model the stage is inert and the makespan winner stands.
    """
    report, best, kp = _plan_objective(
        wf, platform, "reliability", "objective_rel_weighted_ms",
        None, config, overrides)
    rel = best.extras.get("reliability") if best is not None else None
    return ReliabilityResult(report=report, best=best, reliability=rel,
                             k_prime=kp)


def plan_energy(wf, platform: Platform, *,
                reliability_floor: float | None = None,
                speed_levels=(1.0,), config=None,
                **overrides) -> EnergyResult:
    """Plan ``wf`` minimizing energy under a reliability floor.

    Runs the registered ``energy`` pipeline (per-block DVFS greedy, see
    :func:`energy_plan`) across the k' sweep and picks the attempt with
    the smallest total energy from the per-point
    ``objective_energy_total`` observations.  Attempts that cannot
    reach the floor are structurally infeasible; when *no* attempt can,
    the returned report carries an ``Infeasibility`` with
    ``stage="objective"``.
    """
    opts = {"reliability_floor": reliability_floor,
            "speed_levels": tuple(speed_levels)}
    report, best, kp = _plan_objective(
        wf, platform, "energy", "objective_energy_total",
        opts, config, overrides)
    en = best.extras.get("energy") if best is not None else None
    return EnergyResult(report=report, best=best, energy=en, k_prime=kp)
