"""The discrete-event core: replay a block schedule on a platform.

:func:`run_engine` executes a set of :class:`BlockSpec` compute units
(quotient vertices pinned to processors) connected by :class:`EdgeSpec`
transfers, under a pluggable communication model
(:mod:`repro.sim.comm`).  The event loop interleaves three streams —
block-finish events (a heap owned by the engine), release events (for
blocks whose earliest start is gated on an external instant, e.g. a
workflow instance's arrival in pipelined replays) and transfer
completions (owned by the comm model) — processing them in global time
order with deterministic tie-breaking (block finishes first, then
releases, then transfers by edge key).

Semantics (the paper's execution model, §3.3):

* a block occupies its processor for ``duration`` time units, starting
  once **all** incoming transfers have completed, its release time (if
  any) has passed and the processor is free (blocks sharing a
  processor serialize in ready-time order — a no-op for the paper's
  injective mappings, but exactly the interference model pipelined
  multi-instance replays need);
* every outgoing quotient edge starts transferring the moment its
  source block finishes; the comm model decides when it lands.

``run_engine(..., release={vid: t})`` floors each listed block's start
at ``t``: :mod:`repro.throughput` lowers N instances of one workflow
into disjoint vid ranges whose sources are released at the instance
arrival times, so instance i+1's sources overlap instance i's sinks on
the shared processors.  An empty/absent ``release`` map reproduces the
original behavior bit-exactly (every floor is 0.0 and the release heap
never populates — the identity anchor below is unaffected).

Pause / resume
--------------
``run_engine(..., stop_time=t)`` pauses the replay *before* processing
the first event strictly later than ``t``: every block finish and
transfer completion at or before ``t`` is applied, then the engine
state is frozen into an :class:`EngineCheckpoint` attached to the
returned (partial) trace.  :func:`resume_engine` continues a checkpoint
— possibly pausing again — and an uninterrupted run and any
pause/resume chain produce **bit-identical** traces (the event order
never depends on where the pause falls).  This is what
:mod:`repro.scenario` builds on: pause at a platform event, freeze the
completed/in-flight prefix, replan the residual.  A checkpoint holds
the live engine structures (including the comm model) by reference, so
it is single-use: resuming mutates it in place.

Bit-exactness anchor (CPM duality)
----------------------------------
The analytic makespan (Eq. (2)) folds bottom weights from the sinks::

    l_v = w_v/s_v + max_child(c/beta + l_child)

A forward ASAP replay folds the *same* terms from the sources, so in
float64 it agrees only to round-off (addition is not associative).
Running this very engine on the **transposed** DAG — the classic
critical-path-method backward pass — computes each block's finish time
as ``fl(max_child(fl(l_child + c/beta)) + w_v/s_v)``: the identical
operand pairs as the recursion above, merely swapped within each
addition, and IEEE-754 addition *is* commutative.  Hence the backward
pass's horizon equals ``repro.core.makespan.makespan`` **bit-exactly**
under contention-free deterministic settings — a strong end-to-end
check that the event loop implements the paper's model, not an
approximation of it.  :func:`repro.sim.simulate` runs the forward pass
for the trace and the backward pass for the canonical makespan.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field

from repro.obs.tracer import trace_span

from .report import SimEvent

__all__ = ["BlockSpec", "EdgeSpec", "EngineCheckpoint", "EngineTrace",
           "resume_engine", "run_engine", "transpose_edges"]


@dataclass(frozen=True)
class BlockSpec:
    """One schedulable compute unit (a quotient block on a processor)."""

    vid: int
    proc: int
    duration: float


@dataclass(frozen=True)
class EdgeSpec:
    """One aggregated inter-block transfer of ``volume`` units."""

    src: int
    dst: int
    volume: float


@dataclass
class EngineTrace:
    """Raw engine output; :func:`repro.sim.simulate` dresses it up.

    ``checkpoint`` is set iff the run paused at a ``stop_time`` with
    work still outstanding; the trace then covers the executed prefix
    only (``finish`` holds the completed blocks, ``start`` additionally
    the in-flight ones).
    """

    start: dict[int, float]
    finish: dict[int, float]
    xfer_start: dict[tuple[int, int], float]
    xfer_finish: dict[tuple[int, int], float]
    events: list[SimEvent] = field(default_factory=list)
    horizon: float = 0.0
    checkpoint: "EngineCheckpoint | None" = None

    @property
    def paused(self) -> bool:
        return self.checkpoint is not None

    def in_flight(self) -> set[int]:
        """Blocks started but not finished (empty for completed runs)."""
        return set(self.start) - set(self.finish)


@dataclass
class EngineCheckpoint:
    """Frozen mid-replay engine state (see module docstring).

    Opaque to callers: pass it to :func:`resume_engine`.  Holds live
    references (including the comm model), so it is single-use.
    """

    time: float
    by_vid: dict
    out_edges: dict
    pending: dict
    arrival: dict
    proc_busy: dict
    proc_free_at: dict
    proc_queue: dict
    finish_heap: list
    comm: object
    record_events: bool
    trace: EngineTrace
    # (t, vid) heap of future release instants (empty unless the run
    # was given explicit release times)
    release_heap: list = field(default_factory=list)


def transpose_edges(edges: list[EdgeSpec]) -> list[EdgeSpec]:
    """The reversed-DAG edge set (for the CPM backward pass)."""
    return [EdgeSpec(e.dst, e.src, e.volume) for e in edges]


def _drive(cp: EngineCheckpoint, stop_time: float | None,
           initial_ready: list[int]) -> EngineTrace:
    """The event loop, runnable from a fresh state or a checkpoint."""
    by_vid = cp.by_vid
    out_edges = cp.out_edges
    pending = cp.pending
    arrival = cp.arrival
    proc_busy = cp.proc_busy
    proc_free_at = cp.proc_free_at
    proc_queue = cp.proc_queue
    finish_heap = cp.finish_heap
    release_heap = cp.release_heap
    comm = cp.comm
    record_events = cp.record_events
    trace = cp.trace
    events = trace.events

    def start_block(v: int, t: float) -> None:
        b = by_vid[v]
        trace.start[v] = t
        proc_busy[b.proc] = True
        heapq.heappush(finish_heap, (t + b.duration, v))
        if record_events:
            events.append(SimEvent(time=t, kind="task_start",
                                   vertex=v, proc=b.proc))

    def on_ready(v: int, t: float) -> None:
        p = by_vid[v].proc
        if proc_busy.get(p, False):
            heapq.heappush(proc_queue.setdefault(p, []), (t, v))
        else:
            # an idle processor was freed no later than now, so
            # ``max(t, free_at)`` is ``t`` except for ready-at-0 ties
            start_block(v, max(t, proc_free_at.get(p, 0.0)))

    for v in initial_ready:
        on_ready(v, arrival[v])

    while finish_heap or release_heap or comm.has_active():
        nxt = comm.next_completion()
        # ties: block finishes strictly before releases, which precede
        # transfer completions — a finishing block's own outgoing
        # transfers join the comm state before same-instant completions
        # are popped, and a processor freed at t serves a block
        # released at t before later-arriving work
        kind = 0  # 0 = block finish, 1 = release, 2 = transfer
        t_next = finish_heap[0][0] if finish_heap else None
        if release_heap and (t_next is None
                             or release_heap[0][0] < t_next):
            t_next, kind = release_heap[0][0], 1
        if nxt is not None and (t_next is None or nxt[0] < t_next):
            t_next, kind = nxt[0], 2
        take_block = kind == 0
        if stop_time is not None and t_next > stop_time:
            # pause *before* the first event past the stop time: the
            # executed prefix is exactly the uninterrupted run's events
            # with time <= stop_time
            cp.time = stop_time
            trace.checkpoint = cp
            trace.horizon = max(trace.finish.values(), default=0.0)
            return trace
        if take_block:
            t, v = heapq.heappop(finish_heap)
            b = by_vid[v]
            trace.finish[v] = t
            proc_busy[b.proc] = False
            proc_free_at[b.proc] = t
            if record_events:
                events.append(SimEvent(time=t, kind="task_finish",
                                       vertex=v, proc=b.proc))
            for e in out_edges[v]:
                key = (e.src, e.dst)
                comm.start(t, key, e.volume, b.proc, by_vid[e.dst].proc)
                trace.xfer_start[key] = t
                if record_events:
                    events.append(SimEvent(time=t, kind="transfer_start",
                                           edge=key, proc=b.proc))
            q = proc_queue.get(b.proc)
            if q:
                _, w = heapq.heappop(q)
                start_block(w, t)
        elif kind == 1:
            t, v = heapq.heappop(release_heap)
            on_ready(v, t)
        else:
            t, key = comm.complete()
            trace.xfer_finish[key] = t
            dst = key[1]
            if record_events:
                events.append(SimEvent(time=t, kind="transfer_finish",
                                       edge=key, proc=by_vid[dst].proc))
            if t > arrival[dst]:
                arrival[dst] = t
            pending[dst] -= 1
            if pending[dst] == 0:
                if arrival[dst] > t:
                    # release floor still ahead of the last transfer:
                    # defer readiness to the release instant so an
                    # idle processor is not held for a future block
                    heapq.heappush(release_heap, (arrival[dst], dst))
                else:
                    on_ready(dst, arrival[dst])

    if len(trace.finish) != len(by_vid):
        raise ValueError(
            f"{len(by_vid) - len(trace.finish)} blocks never became "
            "ready — the block graph is cyclic"
        )
    trace.checkpoint = None
    trace.horizon = max(trace.finish.values(), default=0.0)
    return trace


def run_engine(blocks: list[BlockSpec], edges: list[EdgeSpec], comm,
               platform, *, record_events: bool = True,
               stop_time: float | None = None,
               release: dict[int, float] | None = None) -> EngineTrace:
    """Replay ``blocks``/``edges`` under ``comm``; see module docstring.

    ``stop_time`` pauses the replay after the last event at or before
    that time; the returned trace then carries a resumable
    :class:`EngineCheckpoint` (``trace.checkpoint``) unless the replay
    already completed.  ``release`` floors listed blocks' start times
    (instance arrivals in pipelined replays; absent blocks are released
    at 0, and an all-zero map is bit-identical to no map).  Raises
    ``ValueError`` when the block graph is cyclic (some block can never
    start) or a release time is negative.
    """
    # one span per replay (wall-clock cost of the virtual-time engine)
    with trace_span("sim.run_engine", n_blocks=len(blocks),
                    n_edges=len(edges)):
        return _run_engine(blocks, edges, comm, platform,
                           record_events=record_events,
                           stop_time=stop_time, release=release)


def _run_engine(blocks: list[BlockSpec], edges: list[EdgeSpec], comm,
                platform, *, record_events: bool = True,
                stop_time: float | None = None,
                release: dict[int, float] | None = None) -> EngineTrace:
    by_vid = {b.vid: b for b in blocks}
    if len(by_vid) != len(blocks):
        raise ValueError("duplicate block vid")
    rel = release or {}
    if any(t < 0 for t in rel.values()):
        raise ValueError("release times must be >= 0")
    out_edges: dict[int, list[EdgeSpec]] = {v: [] for v in by_vid}
    pending: dict[int, int] = {v: 0 for v in by_vid}
    seen_edges: set[tuple[int, int]] = set()
    for e in edges:
        # (src, dst) keys transfers throughout (quotient edges are
        # aggregated); duplicates would alias in the comm models
        if (e.src, e.dst) in seen_edges:
            raise ValueError(f"duplicate edge {(e.src, e.dst)}")
        seen_edges.add((e.src, e.dst))
        out_edges[e.src].append(e)
        pending[e.dst] += 1
    for v in out_edges:
        out_edges[v].sort(key=lambda e: e.dst)

    comm.reset(platform)
    trace = EngineTrace(start={}, finish={}, xfer_start={}, xfer_finish={})
    cp = EngineCheckpoint(
        time=0.0, by_vid=by_vid, out_edges=out_edges, pending=pending,
        # release times double as the arrival floor: a block is never
        # ready before max(its release, its last incoming transfer)
        arrival={v: rel.get(v, 0.0) for v in by_vid},
        # per-processor serialization state (trivial for injective maps)
        proc_busy={}, proc_free_at={}, proc_queue={}, finish_heap=[],
        comm=comm, record_events=record_events, trace=trace,
    )
    # zero-pred blocks released in the future wait on the release heap
    # (starting them eagerly would hold their processor busy from t=0);
    # the rest are ready now, exactly as before
    ready = []
    for v in sorted(by_vid):
        if pending[v] != 0:
            continue
        if cp.arrival[v] > 0.0:
            heapq.heappush(cp.release_heap, (cp.arrival[v], v))
        else:
            ready.append(v)
    return _drive(cp, stop_time, ready)


def resume_engine(checkpoint: EngineCheckpoint, *,
                  stop_time: float | None = None) -> EngineTrace:
    """Continue a paused replay from ``checkpoint``.

    ``stop_time`` (which must be ≥ the checkpoint's pause time) pauses
    again; otherwise the replay runs to completion.  The returned trace
    is the same object the pausing run returned, extended in place —
    resuming to completion yields a trace bit-identical to an
    uninterrupted run.
    """
    if stop_time is not None and stop_time < checkpoint.time:
        raise ValueError(
            f"stop_time {stop_time} precedes checkpoint time "
            f"{checkpoint.time}"
        )
    checkpoint.trace.checkpoint = None
    return _drive(checkpoint, stop_time, [])
