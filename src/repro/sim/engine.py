"""The discrete-event core: replay a block schedule on a platform.

:func:`run_engine` executes a set of :class:`BlockSpec` compute units
(quotient vertices pinned to processors) connected by :class:`EdgeSpec`
transfers, under a pluggable communication model
(:mod:`repro.sim.comm`).  The event loop interleaves two streams —
block-finish events (a heap owned by the engine) and transfer
completions (owned by the comm model) — processing them in global time
order with deterministic tie-breaking (block finishes first, then
transfers by edge key).

Semantics (the paper's execution model, §3.3):

* a block occupies its processor for ``duration`` time units, starting
  once **all** incoming transfers have completed and the processor is
  free (blocks sharing a processor serialize in ready-time order —
  a no-op for the paper's injective mappings);
* every outgoing quotient edge starts transferring the moment its
  source block finishes; the comm model decides when it lands.

Bit-exactness anchor (CPM duality)
----------------------------------
The analytic makespan (Eq. (2)) folds bottom weights from the sinks::

    l_v = w_v/s_v + max_child(c/beta + l_child)

A forward ASAP replay folds the *same* terms from the sources, so in
float64 it agrees only to round-off (addition is not associative).
Running this very engine on the **transposed** DAG — the classic
critical-path-method backward pass — computes each block's finish time
as ``fl(max_child(fl(l_child + c/beta)) + w_v/s_v)``: the identical
operand pairs as the recursion above, merely swapped within each
addition, and IEEE-754 addition *is* commutative.  Hence the backward
pass's horizon equals ``repro.core.makespan.makespan`` **bit-exactly**
under contention-free deterministic settings — a strong end-to-end
check that the event loop implements the paper's model, not an
approximation of it.  :func:`repro.sim.simulate` runs the forward pass
for the trace and the backward pass for the canonical makespan.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field

from .report import SimEvent

__all__ = ["BlockSpec", "EdgeSpec", "EngineTrace", "run_engine",
           "transpose_edges"]


@dataclass(frozen=True)
class BlockSpec:
    """One schedulable compute unit (a quotient block on a processor)."""

    vid: int
    proc: int
    duration: float


@dataclass(frozen=True)
class EdgeSpec:
    """One aggregated inter-block transfer of ``volume`` units."""

    src: int
    dst: int
    volume: float


@dataclass
class EngineTrace:
    """Raw engine output; :func:`repro.sim.simulate` dresses it up."""

    start: dict[int, float]
    finish: dict[int, float]
    xfer_start: dict[tuple[int, int], float]
    xfer_finish: dict[tuple[int, int], float]
    events: list[SimEvent] = field(default_factory=list)
    horizon: float = 0.0


def transpose_edges(edges: list[EdgeSpec]) -> list[EdgeSpec]:
    """The reversed-DAG edge set (for the CPM backward pass)."""
    return [EdgeSpec(e.dst, e.src, e.volume) for e in edges]


def run_engine(blocks: list[BlockSpec], edges: list[EdgeSpec], comm,
               platform, *, record_events: bool = True) -> EngineTrace:
    """Replay ``blocks``/``edges`` under ``comm``; see module docstring.

    Raises ``ValueError`` when the block graph is cyclic (some block
    can never start).
    """
    by_vid = {b.vid: b for b in blocks}
    if len(by_vid) != len(blocks):
        raise ValueError("duplicate block vid")
    out_edges: dict[int, list[EdgeSpec]] = {v: [] for v in by_vid}
    pending: dict[int, int] = {v: 0 for v in by_vid}
    seen_edges: set[tuple[int, int]] = set()
    for e in edges:
        # (src, dst) keys transfers throughout (quotient edges are
        # aggregated); duplicates would alias in the comm models
        if (e.src, e.dst) in seen_edges:
            raise ValueError(f"duplicate edge {(e.src, e.dst)}")
        seen_edges.add((e.src, e.dst))
        out_edges[e.src].append(e)
        pending[e.dst] += 1
    for v in out_edges:
        out_edges[v].sort(key=lambda e: e.dst)

    comm.reset(platform)
    trace = EngineTrace(start={}, finish={}, xfer_start={}, xfer_finish={})
    events = trace.events
    arrival: dict[int, float] = {v: 0.0 for v in by_vid}
    # per-processor serialization state (trivial for injective mappings)
    proc_busy: dict[int, bool] = {}
    proc_free_at: dict[int, float] = {}
    proc_queue: dict[int, list[tuple[float, int]]] = {}
    finish_heap: list[tuple[float, int]] = []

    def start_block(v: int, t: float) -> None:
        b = by_vid[v]
        trace.start[v] = t
        proc_busy[b.proc] = True
        heapq.heappush(finish_heap, (t + b.duration, v))
        if record_events:
            events.append(SimEvent(time=t, kind="task_start",
                                   vertex=v, proc=b.proc))

    def on_ready(v: int, t: float) -> None:
        p = by_vid[v].proc
        if proc_busy.get(p, False):
            heapq.heappush(proc_queue.setdefault(p, []), (t, v))
        else:
            # an idle processor was freed no later than now, so
            # ``max(t, free_at)`` is ``t`` except for ready-at-0 ties
            start_block(v, max(t, proc_free_at.get(p, 0.0)))

    for v in sorted(by_vid):
        if pending[v] == 0:
            on_ready(v, 0.0)

    while finish_heap or comm.has_active():
        nxt = comm.next_completion()
        # ties: block finishes strictly before transfer completions so
        # a finishing block's own outgoing transfers join the comm
        # state before same-instant completions are popped
        if finish_heap and (nxt is None or finish_heap[0][0] <= nxt[0]):
            t, v = heapq.heappop(finish_heap)
            b = by_vid[v]
            trace.finish[v] = t
            proc_busy[b.proc] = False
            proc_free_at[b.proc] = t
            if record_events:
                events.append(SimEvent(time=t, kind="task_finish",
                                       vertex=v, proc=b.proc))
            for e in out_edges[v]:
                key = (e.src, e.dst)
                comm.start(t, key, e.volume, b.proc, by_vid[e.dst].proc)
                trace.xfer_start[key] = t
                if record_events:
                    events.append(SimEvent(time=t, kind="transfer_start",
                                           edge=key, proc=b.proc))
            q = proc_queue.get(b.proc)
            if q:
                _, w = heapq.heappop(q)
                start_block(w, t)
        else:
            t, key = comm.complete()
            trace.xfer_finish[key] = t
            dst = key[1]
            if record_events:
                events.append(SimEvent(time=t, kind="transfer_finish",
                                       edge=key, proc=by_vid[dst].proc))
            if t > arrival[dst]:
                arrival[dst] = t
            pending[dst] -= 1
            if pending[dst] == 0:
                on_ready(dst, arrival[dst])

    if len(trace.finish) != len(blocks):
        raise ValueError(
            f"{len(blocks) - len(trace.finish)} blocks never became "
            "ready — the block graph is cyclic"
        )
    trace.horizon = max(trace.finish.values(), default=0.0)
    return trace
