"""Time-resolved memory-occupancy tracking of a simulated schedule.

The analytic pipeline certifies memory feasibility through *block
sums*: the minimum (or witnessed) traversal peak of every block fits
its processor.  Feasibility of the actual execution, though, is a
property of the **schedule trace** — at every instant, the files live
on a processor plus the running task's footprint must fit (cf.
Eyraud-Dubois et al., "Parallel scheduling of task trees with limited
memory").  This module replays each block's sequential task order
inside its simulated compute interval and builds the occupancy step
function, flagging the exact time/processor/task of any transient
violation.

Memory model — identical to :mod:`repro.core.memdag` (the block
requirement is its traversal peak plus the persistent base):

* while task ``u`` runs, occupancy is ``persistent base + live internal
  files + ext_in(u) + m_u + out_total(u)``;
* between tasks, occupancy is the base plus the live internal files;
* transfers do not add occupancy of their own: an external input
  materializes when its consumer starts and an external output is
  freed when its producer completes, exactly as priced by
  ``block_requirement`` — so a mapping whose blocks fit is violation-
  free in the trace *for the same traversal order*.

The replayed order per block is the planner's witness
(``MappingResult.extras["orders"]``) when present and valid — the order
execution would actually use — falling back to the greedy min-peak
traversal otherwise.  A block whose *witness* order overflows while a
better traversal exists is precisely the "block sums pass, trace
violates" case this tracker exists to expose.
"""
from __future__ import annotations

from repro.core.dag import QuotientGraph, Workflow
from repro.core.memdag import greedy_min_peak_members, occupancy_steps
from repro.core.platform import Platform

from .report import MemoryTrace, MemoryViolation

__all__ = ["build_memory_trace", "pick_block_order"]

#: relative slack mirroring validate_mapping's float tolerance
_TOL = 1 + 1e-9


def _witness_valid(wf: Workflow, members: set[int], order) -> bool:
    if order is None or set(order) != members or len(order) != len(members):
        return False
    done: set[int] = set()
    for u in order:
        if any(p in members and p not in done for p in wf.pred[u]):
            return False
        done.add(u)
    return True


def pick_block_order(wf: Workflow, members: set[int],
                     witness=None) -> list[int]:
    """The traversal the trace replays: valid witness, else greedy."""
    if _witness_valid(wf, members, witness):
        return list(witness)
    _, order = greedy_min_peak_members(wf, sorted(members))
    return order


def build_memory_trace(
    wf: Workflow,
    q: QuotientGraph,
    platform: Platform,
    start: dict[int, float],
    finish: dict[int, float],
    orders: dict[int, list[int]] | None = None,
    *,
    violation_limit: int = 64,
) -> MemoryTrace:
    """Occupancy step functions + violations for a simulated schedule.

    ``start`` / ``finish`` are the engine's block intervals; member
    tasks are laid out sequentially from ``start[vid]`` with durations
    ``w_u / s_p``.  Occupancies come from the shared
    :func:`repro.core.memdag.occupancy_steps` accumulation, so peaks
    are bit-identical to ``base + simulate_peak_members(wf, members,
    order)`` (float rounding is monotone under the constant shift).
    """
    orders = orders or {}
    per_proc: dict[int, list[tuple[float, float]]] = {}
    peak: dict[int, float] = {}
    violations: list[MemoryViolation] = []

    for vid in sorted(q.members):
        members = q.members[vid]
        p = q.proc[vid]
        if p is None:
            raise ValueError(f"block {vid} has no processor")
        cap = platform.memory(p)
        speed = platform.procs[p].speed
        order = pick_block_order(wf, members, orders.get(vid))
        base = sum(wf.persistent[u] for u in members)
        points = per_proc.setdefault(p, [])
        t = start[vid]
        points.append((t, base))
        blk_peak = base
        for u, during, live_after in occupancy_steps(wf, members, order):
            occ = base + during
            points.append((t, occ))
            if occ > blk_peak:
                blk_peak = occ
            if occ > cap * _TOL and len(violations) < violation_limit:
                violations.append(MemoryViolation(
                    time=t, proc=p, vertex=vid, task=u,
                    occupancy=occ, capacity=cap))
            t = t + wf.work[u] / speed
            points.append((t, base + live_after))
        points.append((finish[vid], 0.0))
        if blk_peak > peak.get(p, 0.0):
            peak[p] = blk_peak

    for pts in per_proc.values():
        pts.sort(key=lambda x: x[0])
    violations.sort(key=lambda v: (v.time, v.proc, v.task))
    return MemoryTrace(per_proc=per_proc, peak=peak, violations=violations)
