"""repro.sim — discrete-event execution of mapped workflows.

The optimizer (:mod:`repro.core`) *prices* a mapping with the analytic
bottom-weight formula; this subsystem *executes* one: an event-driven
replay of the quotient schedule on the platform, producing
per-processor timelines, a transfer log, a time-resolved memory
occupancy trace, and robustness envelopes under stochastic durations —
ground truth for everything the analytic proxy abstracts away.

Entry point::

    from repro.sim import simulate
    rep = simulate(schedule(wf, plat).best)        # paper model
    rep.makespan           # == repro.core.makespan() bit-exactly
    rep = simulate(res, comm="fair-share")         # link contention
    rep = simulate(res, jitter=0.2, replicas=32)   # robustness envelope
    print(rep.gantt())

or as a scheduler pipeline stage: ``schedule(wf, plat, simulate=True)``
attaches a :class:`SimReport` to every sweep point's mapping
(``report.sim`` / ``result.extras["sim"]``).

Modules: :mod:`~repro.sim.engine` (event loop + the CPM backward pass
that anchors bit-exactness), :mod:`~repro.sim.comm` (communication
models), :mod:`~repro.sim.memory` (occupancy tracker),
:mod:`~repro.sim.perturb` (seeded jitter), :mod:`~repro.sim.report`
(:class:`SimReport`).

Adding a communication model
----------------------------
Implement the small protocol documented in :mod:`repro.sim.comm`
(``reset`` / ``start`` / ``has_active`` / ``next_completion`` /
``complete``) and pass an instance as ``simulate(..., comm=model)`` —
the engine never special-cases models, it only orders completions.
Only :class:`~repro.sim.comm.ContentionFreeComm` claims the bit-exact
analytic anchor; any other model is measured *against* it.
"""
from __future__ import annotations

from repro.core.makespan import makespan as _analytic_makespan
from repro.core.platform import Platform

from .comm import ContentionFreeComm, FairShareComm, resolve_comm
from .engine import (
    BlockSpec,
    EdgeSpec,
    EngineCheckpoint,
    resume_engine,
    run_engine,
    transpose_edges,
)
from .memory import build_memory_trace, pick_block_order
from .perturb import JitterSpec
from .rng import stream_rng
from .report import (
    JitterEnvelope,
    MemoryTrace,
    MemoryViolation,
    ProcUtilization,
    SimEvent,
    SimReport,
    TransferRecord,
)

__all__ = [
    "BlockSpec",
    "EdgeSpec",
    "EngineCheckpoint",
    "ContentionFreeComm",
    "FairShareComm",
    "JitterEnvelope",
    "JitterSpec",
    "MemoryTrace",
    "MemoryViolation",
    "ProcUtilization",
    "SimEvent",
    "SimReport",
    "TransferRecord",
    "build_memory_trace",
    "build_specs",
    "resolve_comm",
    "resume_engine",
    "run_engine",
    "simulate",
    "stream_rng",
    "trace_memory",
]


class _ReversedLinkView:
    """Platform facade for the CPM backward pass: the engine runs on
    the transposed DAG, so link lookups must swap back to price the
    original direction (matters only for asymmetric overrides)."""

    def __init__(self, platform: Platform) -> None:
        self._platform = platform
        self.bandwidth = platform.bandwidth

    def bandwidth_between(self, i: int, j: int) -> float:
        return self._platform.bandwidth_between(j, i)


def build_specs(q, platform: Platform):
    """Deterministic (blocks, edges) for a fully assigned quotient.

    The lowering :func:`simulate` uses internally, public so drivers
    (e.g. :mod:`repro.scenario`) can run the engine directly — with a
    ``stop_time`` pause — on the exact specs a full simulation uses.
    """
    vids = sorted(q.members)
    blocks = []
    for v in vids:
        p = q.proc[v]
        if p is None:
            raise ValueError(
                f"block {v} is unassigned — simulate needs a complete "
                "mapping (a feasible MappingResult)"
            )
        # the same float expression as the analytic recursion's
        # ``w_v / s_v`` term (bit-exactness anchor)
        blocks.append(BlockSpec(v, p, q.weight[v] / platform.procs[p].speed))
    edges = [EdgeSpec(u, w, c)
             for u in vids
             for w, c in sorted(q.succ[u].items())]
    return blocks, edges


def simulate(
    mapping,
    platform: Platform | None = None,
    *,
    comm="contention-free",
    jitter: float = 0.0,
    jitter_kind: str = "lognormal",
    replicas: int = 0,
    seed: int = 0,
    memory: bool = True,
    record_events: bool = True,
) -> SimReport:
    """Execute a mapping's schedule on a platform; returns a SimReport.

    ``mapping`` is a :class:`~repro.core.baseline.MappingResult` or a
    :class:`~repro.core.scheduler.ScheduleReport` (its ``best`` is
    used).  ``platform`` defaults to the mapping's own platform.

    ``comm`` selects the communication model: ``"contention-free"``
    (alias ``"paper"``) for the analytic model — under which, with no
    jitter, ``SimReport.makespan`` is bit-identical to the analytic
    :func:`repro.core.makespan.makespan` — or ``"fair-share"`` (alias
    ``"contention"``) for fluid max-min fair link/port sharing; any
    object implementing the :mod:`repro.sim.comm` protocol works.

    ``jitter > 0`` additionally replays ``replicas`` (default 16)
    seeded perturbations of the block durations and reports their
    makespans as ``SimReport.envelope``; the headline trace stays
    deterministic.  ``memory=False`` skips the occupancy tracker,
    ``record_events=False`` the event log (both for bulk sweeps).
    """
    res = getattr(mapping, "best", mapping)
    if res is None:
        raise ValueError(
            "schedule report has no feasible mapping to simulate "
            f"({getattr(mapping, 'infeasibility', None)})"
        )
    q = res.quotient
    platform = platform if platform is not None else res.platform
    blocks, edges = build_specs(q, platform)
    comm_model = resolve_comm(comm)

    trace = run_engine(blocks, edges, comm_model, platform,
                       record_events=record_events)

    procs_used = {b.proc for b in blocks}
    injective = len(procs_used) == len(blocks)
    contention_free = isinstance(comm_model, ContentionFreeComm)
    if contention_free and injective:
        # CPM backward pass: bit-exact canonical makespan (see engine).
        # Transposed edges swap each transfer's endpoints, so the link
        # view un-swaps them — asymmetric per-link overrides price the
        # same physical link in both passes.
        back = run_engine(blocks, transpose_edges(edges),
                          ContentionFreeComm(),
                          _ReversedLinkView(platform),
                          record_events=False)
        ms = back.horizon
    else:
        ms = trace.horizon
    exact_anchor = (contention_free and injective
                    and not platform.link_bandwidth)

    analytic = _analytic_makespan(q, platform)

    by_proc: dict[int, list[int]] = {}
    for b in sorted(blocks, key=lambda b: trace.start[b.vid]):
        by_proc.setdefault(b.proc, []).append(b.vid)
    span = ms if ms > 0 else 1.0
    procs = []
    for p in sorted(by_proc):
        busy = sum(trace.finish[v] - trace.start[v] for v in by_proc[p])
        procs.append(ProcUtilization(
            proc=p, name=platform.procs[p].name,
            blocks=tuple(by_proc[p]), busy_s=busy,
            idle_s=max(0.0, ms - busy), utilization=busy / span))

    mem_trace = None
    if memory:
        mem_trace = build_memory_trace(
            q.wf, q, platform, trace.start, trace.finish,
            orders=res.extras.get("orders"))

    envelope = None
    if jitter > 0.0:
        spec = JitterSpec(jitter, jitter_kind)
        n_rep = replicas if replicas > 0 else 16
        makespans = []
        for i in range(n_rep):
            f = spec.factors(len(blocks), seed, i)
            jb = [BlockSpec(b.vid, b.proc, b.duration * float(f[k]))
                  for k, b in enumerate(blocks)]
            jt = run_engine(jb, edges, comm_model, platform,
                            record_events=False)
            makespans.append(jt.horizon)
        envelope = JitterEnvelope(amount=jitter, kind=jitter_kind,
                                  seed=seed, makespans=makespans)

    transfers = [
        TransferRecord(src=e.src, dst=e.dst, volume=e.volume,
                       start=trace.xfer_start[(e.src, e.dst)],
                       finish=trace.xfer_finish[(e.src, e.dst)])
        for e in edges
    ]
    report = SimReport(
        comm=comm_model.name,
        makespan=ms,
        horizon=trace.horizon,
        analytic_makespan=analytic,
        exact_anchor=exact_anchor,
        platform_name=platform.name,
        n_tasks=q.wf.n,
        n_blocks=len(blocks),
        block_proc={b.vid: b.proc for b in blocks},
        block_start=dict(trace.start),
        block_finish=dict(trace.finish),
        transfers=transfers,
        procs=procs,
        events=trace.events,
        memory=mem_trace,
        envelope=envelope,
    )
    if platform.power or platform.failure_rates:
        from repro.objectives import energy_from_sim  # deferred

        report.energy = energy_from_sim(report, platform)
    return report


def trace_memory(mapping, platform: Platform | None = None,
                 *, comm="contention-free") -> MemoryTrace:
    """Just the time-resolved memory trace of a mapping's schedule.

    One forward engine pass plus the occupancy tracker — the lean path
    ``validate_mapping(..., memory_trace=True)`` uses (no backward
    pass, no analytic sweep, no event/transfer bookkeeping).
    """
    res = getattr(mapping, "best", mapping)
    if res is None:
        raise ValueError("schedule report has no feasible mapping to trace")
    q = res.quotient
    platform = platform if platform is not None else res.platform
    blocks, edges = build_specs(q, platform)
    trace = run_engine(blocks, edges, resolve_comm(comm), platform,
                       record_events=False)
    return build_memory_trace(q.wf, q, platform, trace.start, trace.finish,
                              orders=res.extras.get("orders"))
