"""Shared seeded-RNG construction for every stochastic subsystem.

All stochastic draws in the codebase — duration jitter
(:mod:`repro.sim.perturb`), arrival processes
(:mod:`repro.throughput.arrivals`), future failure-trace generators —
go through :func:`stream_rng` so the determinism contract is uniform:
the same ``(tag, seed, stream)`` triple always reproduces the same
draws regardless of call order, process, or platform.  ``tag``
namespaces the :class:`numpy.random.SeedSequence` per subsystem, so two
consumers of the *same user-facing seed* never collide; ``stream``
separates independent replicas/streams under one seed (jitter replicas,
tenant arrival streams).
"""
from __future__ import annotations

import numpy as np

__all__ = ["stream_rng"]


def stream_rng(tag: int, seed: int, stream: int = 0) -> np.random.Generator:
    """A PCG64 generator seeded on the ``(tag, seed, stream)`` triple.

    Exactly ``np.random.default_rng([tag, seed, stream])`` — kept in
    one place so every subsystem's seeding is bit-compatible with the
    pre-existing jitter contract (`JitterSpec.factors` produced
    ``default_rng([_STREAM_TAG, seed, replica])`` since PR 3; this
    helper generalizes it without changing a single draw).
    """
    return np.random.default_rng([int(tag), int(seed), int(stream)])
