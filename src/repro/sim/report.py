"""Structured results of a schedule replay (:func:`repro.sim.simulate`).

A :class:`SimReport` is the simulation analogue of
:class:`repro.core.scheduler.ScheduleReport`: a JSON-serializable record
of *what happened* when a mapping was executed on a platform — block
start/finish times, per-processor utilization, the transfer log, the
time-resolved memory occupancy (with violations), and the robustness
envelope under stochastic task durations.

``makespan`` vs ``horizon``
---------------------------
``horizon`` is the last block-finish time of the forward (ASAP) replay
— the value every trace artifact (Gantt, events, memory timeline) is
consistent with.  ``makespan`` is the canonical simulated makespan: in
the deterministic contention-free regime it comes from the engine's CPM
backward pass, whose per-op float roundings mirror the analytic
bottom-weight recursion exactly (see :mod:`repro.sim.engine`), so it is
*bit-identical* to :func:`repro.core.makespan.makespan` — that is the
subsystem's correctness anchor, and ``exact_anchor`` records when it is
in force.  Under contention or jitter there is no analytic counterpart
and ``makespan == horizon``.  The two regimes agree to float round-off.
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass, field

__all__ = [
    "SimEvent",
    "TransferRecord",
    "ProcUtilization",
    "MemoryViolation",
    "MemoryTrace",
    "JitterEnvelope",
    "SimReport",
]


@dataclass(frozen=True)
class SimEvent:
    """One entry of the event log.

    ``kind`` is one of ``task_start`` / ``task_finish`` (a quotient
    block beginning/ending its compute interval on ``proc``) or
    ``transfer_start`` / ``transfer_finish`` (the aggregated quotient
    edge ``edge`` moving between processors).
    """

    time: float
    kind: str
    vertex: int | None = None
    edge: tuple[int, int] | None = None
    proc: int | None = None

    def to_list(self) -> list:
        return [self.time, self.kind, self.vertex,
                list(self.edge) if self.edge else None, self.proc]

    @classmethod
    def from_list(cls, row: list) -> "SimEvent":
        t, kind, vertex, edge, proc = row
        return cls(time=t, kind=kind, vertex=vertex,
                   edge=tuple(edge) if edge else None, proc=proc)


@dataclass(frozen=True)
class TransferRecord:
    """One aggregated inter-block transfer, with its realized interval
    (under contention the duration exceeds ``volume / β``)."""

    src: int
    dst: int
    volume: float
    start: float
    finish: float

    def to_list(self) -> list:
        return [self.src, self.dst, self.volume, self.start, self.finish]

    @classmethod
    def from_list(cls, row: list) -> "TransferRecord":
        return cls(*row)


@dataclass(frozen=True)
class ProcUtilization:
    """Busy/idle accounting for one processor that hosts blocks."""

    proc: int
    name: str
    blocks: tuple[int, ...]
    busy_s: float
    idle_s: float
    utilization: float

    def to_dict(self) -> dict:
        return {
            "proc": self.proc, "name": self.name,
            "blocks": list(self.blocks), "busy_s": self.busy_s,
            "idle_s": self.idle_s, "utilization": self.utilization,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "ProcUtilization":
        d = dict(d)
        d["blocks"] = tuple(d["blocks"])
        return cls(**d)


@dataclass(frozen=True)
class MemoryViolation:
    """An instant where a processor's occupancy exceeds its memory.

    ``instance`` pinpoints the workflow instance whose task pushed the
    occupancy over in pipelined multi-instance replays
    (:mod:`repro.throughput`); ``None`` for single-instance traces.
    """

    time: float
    proc: int
    vertex: int
    task: int
    occupancy: float
    capacity: float
    instance: int | None = None

    def to_dict(self) -> dict:
        d = {
            "time": self.time, "proc": self.proc, "vertex": self.vertex,
            "task": self.task, "occupancy": self.occupancy,
            "capacity": self.capacity,
        }
        if self.instance is not None:
            d["instance"] = self.instance
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "MemoryViolation":
        return cls(**d)


@dataclass
class MemoryTrace:
    """Time-resolved memory occupancy per processor.

    ``per_proc[j]`` is the step function as ``(time, occupancy)``
    breakpoints (occupancy holds from each point to the next);
    ``peak[j]`` its maximum; ``violations`` every sampled instant whose
    occupancy exceeded the processor memory (sorted by time, capped at
    the tracker's ``violation_limit``).
    """

    per_proc: dict[int, list[tuple[float, float]]]
    peak: dict[int, float]
    violations: list[MemoryViolation] = field(default_factory=list)

    @property
    def feasible(self) -> bool:
        return not self.violations

    def to_dict(self) -> dict:
        return {
            "per_proc": [[j, [list(pt) for pt in pts]]
                         for j, pts in sorted(self.per_proc.items())],
            "peak": [[j, v] for j, v in sorted(self.peak.items())],
            "violations": [v.to_dict() for v in self.violations],
        }

    @classmethod
    def from_dict(cls, d: dict) -> "MemoryTrace":
        return cls(
            per_proc={j: [tuple(pt) for pt in pts]
                      for j, pts in d["per_proc"]},
            peak={j: v for j, v in d["peak"]},
            violations=[MemoryViolation.from_dict(v)
                        for v in d.get("violations", [])],
        )


@dataclass
class JitterEnvelope:
    """Makespans of N replicas with stochastically perturbed durations."""

    amount: float
    kind: str
    seed: int
    makespans: list[float]

    @property
    def lo(self) -> float:
        return min(self.makespans)

    @property
    def hi(self) -> float:
        return max(self.makespans)

    @property
    def mean(self) -> float:
        return sum(self.makespans) / len(self.makespans)

    @property
    def std(self) -> float:
        m = self.mean
        return math.sqrt(sum((x - m) ** 2 for x in self.makespans)
                         / len(self.makespans))

    def to_dict(self) -> dict:
        return {"amount": self.amount, "kind": self.kind,
                "seed": self.seed, "makespans": list(self.makespans)}

    @classmethod
    def from_dict(cls, d: dict) -> "JitterEnvelope":
        return cls(**d)


@dataclass
class SimReport:
    """Everything :func:`repro.sim.simulate` observed — see the module
    docstring for the ``makespan`` / ``horizon`` distinction."""

    comm: str
    makespan: float
    horizon: float
    analytic_makespan: float | None
    exact_anchor: bool
    platform_name: str
    n_tasks: int
    n_blocks: int
    block_proc: dict[int, int]
    block_start: dict[int, float]
    block_finish: dict[int, float]
    transfers: list[TransferRecord]
    procs: list[ProcUtilization]
    events: list[SimEvent] = field(default_factory=list)
    memory: MemoryTrace | None = None
    envelope: JitterEnvelope | None = None
    #: energy/exposure accounting from the per-proc busy integrals
    #: (:func:`repro.objectives.energy_from_sim`) — attached when the
    #: platform carries a failure or power model, else ``None``
    energy: dict | None = None

    # -------------------------------------------------------------- #
    def to_dict(self) -> dict:
        return {
            "comm": self.comm,
            "makespan": self.makespan,
            "horizon": self.horizon,
            "analytic_makespan": self.analytic_makespan,
            "exact_anchor": self.exact_anchor,
            "platform_name": self.platform_name,
            "n_tasks": self.n_tasks,
            "n_blocks": self.n_blocks,
            "blocks": [[v, self.block_proc[v], self.block_start[v],
                        self.block_finish[v]]
                       for v in sorted(self.block_proc)],
            "transfers": [t.to_list() for t in self.transfers],
            "procs": [p.to_dict() for p in self.procs],
            "events": [e.to_list() for e in self.events],
            "memory": self.memory.to_dict() if self.memory else None,
            "envelope": self.envelope.to_dict() if self.envelope else None,
            "energy": self.energy,
        }

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_dict(cls, d: dict) -> "SimReport":
        blocks = d.get("blocks", [])
        return cls(
            comm=d["comm"],
            makespan=d["makespan"],
            horizon=d["horizon"],
            analytic_makespan=d.get("analytic_makespan"),
            exact_anchor=d.get("exact_anchor", False),
            platform_name=d.get("platform_name", "?"),
            n_tasks=d.get("n_tasks", 0),
            n_blocks=d.get("n_blocks", len(blocks)),
            block_proc={v: p for v, p, _, _ in blocks},
            block_start={v: s for v, _, s, _ in blocks},
            block_finish={v: f for v, _, _, f in blocks},
            transfers=[TransferRecord.from_list(t)
                       for t in d.get("transfers", [])],
            procs=[ProcUtilization.from_dict(p) for p in d.get("procs", [])],
            events=[SimEvent.from_list(e) for e in d.get("events", [])],
            memory=(MemoryTrace.from_dict(d["memory"])
                    if d.get("memory") else None),
            envelope=(JitterEnvelope.from_dict(d["envelope"])
                      if d.get("envelope") else None),
            energy=d.get("energy"),
        )

    @classmethod
    def from_json(cls, s: str) -> "SimReport":
        return cls.from_dict(json.loads(s))

    # -------------------------------------------------------------- #
    def gantt(self, width: int = 64) -> str:
        """ASCII Gantt chart: one row per block-hosting processor.

        ``█`` marks the block's compute interval (its id is inlaid when
        it fits), ``·`` idle time.  The axis spans ``[0, horizon]``.
        """
        h = self.horizon if self.horizon > 0 else 1.0
        lines = [f"{'':>14s}  t=0{'':{max(width - 12, 1)}s}"
                 f"t={h:.6g}"]
        for pu in sorted(self.procs, key=lambda p: p.proc):
            row = ["·"] * width
            for vid in pu.blocks:
                s, f = self.block_start[vid], self.block_finish[vid]
                a = min(int(s / h * width), width - 1)
                b = max(a + 1, min(int(math.ceil(f / h * width)), width))
                for x in range(a, b):
                    row[x] = "█"
                label = str(vid)
                if b - a >= len(label) + 2:
                    row[a + 1:a + 1 + len(label)] = label
            lines.append(f"{pu.name:>12.12s}  |{''.join(row)}| "
                         f"busy {pu.utilization:6.1%}")
        return "\n".join(lines)
