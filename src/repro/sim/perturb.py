"""Seeded stochastic perturbation of block durations.

Real task runtimes jitter around their nominal work/speed estimate;
replaying a plan under N seeded perturbations yields a robustness
envelope for its makespan (``SimReport.envelope``).  Factors are drawn
per *block* (the engine's schedulable unit) and multiply its nominal
duration; the same ``(seed, replica)`` pair always reproduces the same
factors regardless of call order, process, or platform — the
determinism contract the scheduler's parallel paths rely on.

Kinds:

* ``lognormal`` — ``exp(N(-amount^2/2, amount))``: mean-1 multiplicative
  noise, the classic heavy-tailed runtime model (``amount`` = sigma of
  the underlying normal);
* ``uniform`` — ``U(max(0, 1-amount), 1+amount)``: bounded symmetric
  jitter.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .rng import stream_rng

__all__ = ["JitterSpec"]

# namespaces the SeedSequence so sim draws never collide with other
# consumers of the same user-facing seed (e.g. the arrival processes
# in repro.throughput, which use their own tag through the same
# stream_rng helper)
_STREAM_TAG = 0x51D0


@dataclass(frozen=True)
class JitterSpec:
    """How to perturb durations: ``kind`` ∈ {lognormal, uniform}."""

    amount: float
    kind: str = "lognormal"

    def __post_init__(self) -> None:
        if self.amount < 0:
            raise ValueError("jitter amount must be >= 0")
        if self.kind not in ("lognormal", "uniform"):
            raise ValueError(f"unknown jitter kind {self.kind!r}")

    def factors(self, n: int, seed: int, replica: int) -> np.ndarray:
        """``n`` multiplicative duration factors for one replica."""
        rng = stream_rng(_STREAM_TAG, seed, replica)
        a = self.amount
        if a == 0.0:
            return np.ones(n)
        if self.kind == "lognormal":
            return np.exp(rng.normal(-0.5 * a * a, a, size=n))
        return rng.uniform(max(0.0, 1.0 - a), 1.0 + a, size=n)
