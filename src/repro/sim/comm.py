"""Pluggable communication models for the schedule-execution engine.

The engine (:mod:`repro.sim.engine`) delegates *all* transfer timing to
a communication model.  A model is any object with this protocol::

    name: str                  # reported in SimReport.comm
    reset(platform)            # called once per engine run
    start(t, key, volume, src_proc, dst_proc)
    has_active() -> bool
    next_completion() -> (time, key) | None   # earliest, without popping
    complete() -> (time, key)                 # pop that completion

``key`` is the quotient edge ``(src_vid, dst_vid)``; completions must
come out in deterministic ``(time, key)`` order.  Register nothing —
pass an instance straight to :func:`repro.sim.simulate(..., comm=...)`.

Two models ship:

* :class:`ContentionFreeComm` — the paper's model: every transfer gets
  the full link bandwidth, so its duration is exactly ``volume /
  bandwidth_between(src, dst)``.  This is the model under which the
  simulated makespan is bit-identical to the analytic bottom-weight
  makespan (the correctness anchor; see :mod:`repro.sim.engine`).
* :class:`FairShareComm` — fluid max-min fair sharing: each transfer
  is constrained by its source's egress port, its destination's
  ingress port and the directed link, all defaulting to the platform's
  ``bandwidth_between``; concurrent transfers split each resource
  fairly (progressive-filling water-fill, recomputed at every transfer
  start/finish).  A block fanning out to many successors — free in the
  analytic model — serializes on its egress port here, which is the
  main source of the analytic-vs-simulated gap that ``make bench-sim``
  measures.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass

from repro.core.platform import Platform

__all__ = ["ContentionFreeComm", "FairShareComm", "resolve_comm"]


class ContentionFreeComm:
    """Paper model: dedicated bandwidth, duration ``volume / β_link``."""

    name = "contention-free"

    def reset(self, platform: Platform) -> None:
        self._bw = platform.bandwidth_between
        self._heap: list[tuple[float, tuple[int, int]]] = []

    def start(self, t: float, key: tuple[int, int], volume: float,
              src_proc: int, dst_proc: int) -> None:
        bw = self._bw(src_proc, dst_proc)
        # ``t + volume / bw`` — the exact float expression of the
        # analytic recursion's ``c / beta + l`` term (addition is
        # commutative in IEEE-754, so the operand order is immaterial
        # for the bit-exactness anchor).
        delay = 0.0 if math.isinf(bw) else volume / bw
        heapq.heappush(self._heap, (t + delay, key))

    def has_active(self) -> bool:
        return bool(self._heap)

    def next_completion(self) -> tuple[float, tuple[int, int]] | None:
        return self._heap[0] if self._heap else None

    def complete(self) -> tuple[float, tuple[int, int]]:
        return heapq.heappop(self._heap)


@dataclass
class _Flow:
    key: tuple[int, int]
    remaining: float
    resources: tuple
    rate: float = 0.0


class FairShareComm:
    """Fluid max-min fair sharing over egress / ingress / link capacity.

    Between events every active transfer progresses at the max-min fair
    rate of the current flow set; the allocation is recomputed whenever
    a transfer starts or finishes (piecewise-constant rates).  With a
    single active transfer this degenerates to the contention-free
    model.  ``egress`` / ``ingress`` / ``link`` select which resources
    constrain a flow; capacities default to the platform's
    ``bandwidth_between`` (per-proc ports use the uniform β).
    """

    def __init__(self, *, egress: bool = True, ingress: bool = True,
                 link: bool = True) -> None:
        if not (egress or ingress or link):
            raise ValueError("at least one resource class must be active")
        self.egress = egress
        self.ingress = ingress
        self.link = link

    @property
    def name(self) -> str:
        tags = [t for t, on in (("egress", self.egress),
                                ("ingress", self.ingress),
                                ("link", self.link)) if on]
        return "fair-share(" + "+".join(tags) + ")"

    # -------------------------------------------------------------- #
    def reset(self, platform: Platform) -> None:
        self._platform = platform
        self._flows: dict[tuple[int, int], _Flow] = {}
        self._t = 0.0
        self._next: tuple[float, tuple[int, int]] | None = None

    def _resources(self, sp: int, dp: int) -> tuple:
        if sp == dp:
            # data staying on a processor is not transferred: no port
            # or link consumption (the flow completes instantly, as in
            # the contention-free model)
            return ()
        r = []
        if self.egress:
            r.append(("out", sp))
        if self.ingress:
            r.append(("in", dp))
        if self.link:
            r.append(("lnk", sp, dp))
        return tuple(r)

    def _capacity(self, res: tuple) -> float:
        if res[0] == "lnk":
            return self._platform.bandwidth_between(res[1], res[2])
        return self._platform.bandwidth

    # -------------------------------------------------------------- #
    def _advance(self, t: float) -> None:
        dt = t - self._t
        if dt > 0.0:
            for f in self._flows.values():
                if not math.isinf(f.rate):
                    f.remaining = max(0.0, f.remaining - f.rate * dt)
                else:
                    f.remaining = 0.0
        self._t = t

    def _reallocate(self) -> None:
        """Max-min fair rates via progressive filling (water-fill)."""
        flows = self._flows
        if not flows:
            self._next = None
            return
        members: dict[tuple, list] = {}
        for f in flows.values():
            for r in f.resources:
                members.setdefault(r, []).append(f.key)
        headroom = {r: self._capacity(r) for r in members}
        unfixed = set(flows)
        while unfixed:
            best = None
            for r in sorted(members):
                live = [k for k in members[r] if k in unfixed]
                if not live:
                    continue
                h = headroom[r] / len(live)
                if best is None or h < best[0]:
                    best = (h, r, live)
            if best is None:  # every remaining flow only on inf resources
                for k in unfixed:
                    flows[k].rate = math.inf
                break
            h, _, live = best
            for k in live:
                f = flows[k]
                f.rate = h
                unfixed.discard(k)
                for rr in f.resources:
                    headroom[rr] = max(0.0, headroom[rr] - h)
        # earliest completion under the new rates, ties by edge key
        nxt = None
        for k in sorted(flows):
            f = flows[k]
            done = self._t if (f.remaining <= 0.0 or math.isinf(f.rate)) \
                else self._t + f.remaining / f.rate
            if nxt is None or done < nxt[0]:
                nxt = (done, k)
        self._next = nxt

    # -------------------------------------------------------------- #
    def start(self, t: float, key: tuple[int, int], volume: float,
              src_proc: int, dst_proc: int) -> None:
        self._advance(t)
        self._flows[key] = _Flow(key, volume, self._resources(src_proc,
                                                              dst_proc))
        self._reallocate()

    def has_active(self) -> bool:
        return bool(self._flows)

    def next_completion(self) -> tuple[float, tuple[int, int]] | None:
        return self._next

    def complete(self) -> tuple[float, tuple[int, int]]:
        t, key = self._next
        self._advance(t)
        del self._flows[key]
        self._reallocate()
        return t, key


_ALIASES = {
    "contention-free": ContentionFreeComm,
    "paper": ContentionFreeComm,
    "analytic": ContentionFreeComm,
    "beta": ContentionFreeComm,
    "fair-share": FairShareComm,
    "fairshare": FairShareComm,
    "contention": FairShareComm,
}


def resolve_comm(comm) -> object:
    """A comm-model instance from a name, class or ready instance."""
    if isinstance(comm, str):
        try:
            return _ALIASES[comm]()
        except KeyError:
            raise ValueError(
                f"unknown comm model {comm!r}; choose from "
                f"{sorted(_ALIASES)} or pass an instance"
            ) from None
    if isinstance(comm, type):
        return comm()
    return comm
