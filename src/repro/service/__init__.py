"""repro.service — scheduler-as-a-service: continuous multi-workflow
operation with a fingerprinted plan cache.

The paper's mapper plans one workflow, once.  This subsystem runs the
mapper as a long-lived *service*: workflows arrive over virtual time on
behalf of tenants, pass admission control (per-tenant quotas, weighted
fair-share ordering), get planned onto a carved slice of the shared
platform — through a plan cache keyed on a structural workflow
fingerprint, so repeat pipelines skip the k' sweep entirely
(:meth:`Scheduler.seeded <repro.core.scheduler.Scheduler.seeded>`) —
execute in the discrete-event simulator, and survive mid-run platform
events by warm-start replanning (:func:`repro.scenario.freeze_prefix`).
Everything is deterministic in virtual time: the same submission trace
and event timeline yield a bit-identical :class:`ServiceTrace`,
whatever the wall clock or worker count did.

::

    from repro.core import sample_platform
    from repro.core.workflows import random_layered
    from repro.service import Submission, run_service

    subs = [Submission(random_layered(80, seed=s), tenant="alice",
                       arrival_t=10.0 * s) for s in range(4)]
    report = run_service(subs, sample_platform(8))
    report.completed            # JobRecords with latency/queue-wait
    report.cache_hit_rate       # plan-cache effectiveness
    print(report.gantt())       # stitched multi-job timeline

Structured outcomes, never exceptions: a malformed payload or quota
violation becomes a :class:`Rejection`; transient pressure becomes a
logged :class:`Deferral`; a job that cannot be planned even with the
whole platform free carries the scheduler's structured
:class:`~repro.core.scheduler.Infeasibility`.  The identity anchor:
one submission at t=0 with no events and empty quotas reproduces
``Scheduler(cfg).schedule(wf, platform)`` with ``simulate=True``
bit-exactly.

Sustained admission of *repeat* arrivals of one workflow — plan once
through the cache, replicate onto idle processors, replay the whole
stream in one pipelined simulation — is :func:`run_sustained` (built
on :mod:`repro.throughput`); the report carries instances/s, the
per-instance latency histogram and the saturation rate.
"""
from __future__ import annotations

from .admission import FairQueue, QuotaConfig, TenantQuota
from .fingerprint import (
    WorkflowFingerprint,
    fingerprint_workflow,
    platform_signature,
)
from .loop import ServiceConfig, WorkflowService, run_service
from .plancache import CachedPlan, PlanCache
from .report import JobRecord, ServiceReport, ServiceTrace
from .submission import Deferral, Rejection, Submission, resolve_workflow
from .sustained import run_sustained

__all__ = [
    "CachedPlan",
    "Deferral",
    "FairQueue",
    "JobRecord",
    "PlanCache",
    "QuotaConfig",
    "Rejection",
    "ServiceConfig",
    "ServiceReport",
    "ServiceTrace",
    "Submission",
    "TenantQuota",
    "WorkflowFingerprint",
    "WorkflowService",
    "fingerprint_workflow",
    "platform_signature",
    "resolve_workflow",
    "run_service",
    "run_sustained",
]
