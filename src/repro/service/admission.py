"""Admission control: per-tenant quotas and weighted fair-share order.

Quotas bound what one tenant can take (queue depth, concurrent jobs,
per-submission size); the :class:`FairQueue` decides *who goes next*
when capacity frees up.  Ordering is classic weighted fair queueing on
accumulated service: each tenant accrues virtual service equal to the
total work it has dispatched divided by its weight, and the queue
always offers the waiting job of the least-served tenant first (ties:
earlier arrival, then submission order — fully deterministic).  A
tenant with weight 2 therefore drains twice the work per unit of
contention as a weight-1 tenant, and an idle tenant's first job jumps
ahead of a heavy tenant's backlog.

Quota checks return structured verdicts through the service
(:class:`~repro.service.submission.Rejection` /
:class:`~repro.service.submission.Deferral`) — admission never raises
on untrusted input.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Iterable

if TYPE_CHECKING:  # pragma: no cover - typing only
    from .loop import _Job

__all__ = ["FairQueue", "QuotaConfig", "TenantQuota"]


@dataclass(frozen=True)
class TenantQuota:
    """Limits for one tenant (``None`` = unlimited).

    ``weight`` scales the tenant's fair share (2.0 = twice the
    service); ``max_pending`` bounds queued-but-not-dispatched jobs,
    ``max_running`` bounds concurrently executing jobs, ``max_tasks``
    bounds a single submission's task count.
    """

    weight: float = 1.0
    max_pending: int | None = None
    max_running: int | None = None
    max_tasks: int | None = None

    def __post_init__(self) -> None:
        if not self.weight > 0:
            raise ValueError(
                f"tenant weight must be positive, got {self.weight!r}")


@dataclass
class QuotaConfig:
    """Per-tenant quotas with a default for unlisted tenants.

    The empty config (no tenants, default :class:`TenantQuota`) is the
    identity: every submission admitted, FIFO order degenerates to
    arrival order — the service's single-job anchor relies on this.
    """

    tenants: dict[str, TenantQuota] = field(default_factory=dict)
    default: TenantQuota = field(default_factory=TenantQuota)

    def quota(self, tenant: str) -> TenantQuota:
        return self.tenants.get(tenant, self.default)


class FairQueue:
    """Deterministic weighted fair-share queue over admitted jobs."""

    def __init__(self, quotas: QuotaConfig) -> None:
        self._quotas = quotas
        self._jobs: list["_Job"] = []
        self._service: dict[str, float] = {}

    def __len__(self) -> int:
        return len(self._jobs)

    def push(self, job: "_Job") -> None:
        self._jobs.append(job)

    def remove(self, job: "_Job") -> None:
        self._jobs.remove(job)

    def pending(self, tenant: str) -> int:
        return sum(1 for j in self._jobs if j.tenant == tenant)

    def charge(self, tenant: str, amount: float) -> None:
        """Accrue ``amount`` of raw service (dispatched work) to
        ``tenant`` — normalization by weight happens at ordering."""
        self._service[tenant] = self._service.get(tenant, 0.0) + amount

    def normalized_service(self, tenant: str) -> float:
        return (self._service.get(tenant, 0.0)
                / self._quotas.quota(tenant).weight)

    def fair_order(self) -> Iterable["_Job"]:
        """Waiting jobs, least-served tenant first (see module doc).

        A snapshot: callers may dispatch (and :meth:`remove`) while
        iterating.  Service accrued mid-iteration does not reorder the
        current round — one round, one consistent ordering.
        """
        return sorted(
            self._jobs,
            key=lambda j: (self.normalized_service(j.tenant),
                           j.arrival_t, j.seq),
        )
