"""DAG fingerprints and platform signatures for the plan cache.

A :class:`WorkflowFingerprint` is a canonical content digest of a
workflow: a SHA-256 over a fixed byte encoding of the task count, every
task's exact weights (work / memory / persistent, as little-endian
IEEE-754 doubles) and every edge with its exact cost, in task-id order.
Two workflows collide only if they are the same instance bit for bit —
same shape *and* same weights — so a cache hit can never seed from a
look-alike DAG with different numbers (the "no false hits" property
test in ``tests/test_service.py``).  The digest depends only on
workflow *content*, never on process state, object identity or hash
randomization, so it is stable across process restarts — a persisted
plan cache stays valid.

Task numbering is part of the identity: the same pipeline submitted
with permuted task ids fingerprints differently.  That trades a few
false *misses* (harmless: the job just plans cold) for a digest that is
O(V + E) with no canonical-labeling search — the millions-of-users case
is many submissions of the *same generated instance*, which reuses ids.

The coarse ``work_hist`` / ``mem_hist`` log-histograms ride along for
observability (which traffic classes hit the cache) and as a cheap
pre-filter for future approximate matching; they do **not** loosen the
key — the digest alone decides equality.

:func:`platform_signature` plays the same role for the platform side of
a cache key: processor (speed, memory) pairs in index order, the
uniform bandwidth, and any per-link overrides.  Platform *names* are
deliberately excluded — ``Platform.without`` renames carved
sub-platforms (``"…-degraded"``), and a plan is reusable wherever the
same processors are free, whatever the carve is called.
"""
from __future__ import annotations

import hashlib
import math
import struct
from dataclasses import dataclass

from repro.core.dag import Workflow
from repro.core.platform import Platform

__all__ = [
    "WorkflowFingerprint",
    "fingerprint_workflow",
    "platform_signature",
]

_HEADER = b"repro-fp-1\x00"
_HIST_BINS = 8
_HIST_LO = -3.0   # log10 bucket range: 1e-3 .. 1e9
_HIST_HI = 9.0


def _f8(x: float) -> bytes:
    return struct.pack("<d", float(x))


def _i8(x: int) -> bytes:
    return struct.pack("<q", int(x))


def _log_hist(values) -> tuple[int, ...]:
    hist = [0] * _HIST_BINS
    for x in values:
        if x <= 0:
            b = 0
        else:
            t = (math.log10(x) - _HIST_LO) / (_HIST_HI - _HIST_LO)
            b = min(_HIST_BINS - 1, max(0, int(t * _HIST_BINS)))
        hist[b] += 1
    return tuple(hist)


@dataclass(frozen=True)
class WorkflowFingerprint:
    """Canonical identity of a workflow: exact digest + coarse shape."""

    digest: str                 # SHA-256 hex over the canonical encoding
    n: int
    n_edges: int
    work_hist: tuple[int, ...]  # log10-bucketed work weights
    mem_hist: tuple[int, ...]   # log10-bucketed memory weights

    def to_dict(self) -> dict:
        return {
            "digest": self.digest,
            "n": self.n,
            "n_edges": self.n_edges,
            "work_hist": list(self.work_hist),
            "mem_hist": list(self.mem_hist),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "WorkflowFingerprint":
        return cls(
            digest=d["digest"], n=int(d["n"]),
            n_edges=int(d["n_edges"]),
            work_hist=tuple(int(x) for x in d["work_hist"]),
            mem_hist=tuple(int(x) for x in d["mem_hist"]),
        )


def fingerprint_workflow(wf: Workflow) -> WorkflowFingerprint:
    """Digest ``wf``'s exact content; see the module docstring."""
    h = hashlib.sha256()
    h.update(_HEADER)
    h.update(_i8(wf.n))
    h.update(_i8(wf.n_edges))
    for u in range(wf.n):
        h.update(_f8(wf.work[u]))
        h.update(_f8(wf.mem[u]))
        h.update(_f8(wf.persistent[u]))
    for u in range(wf.n):
        for v in sorted(wf.succ[u]):
            h.update(_i8(u))
            h.update(_i8(v))
            h.update(_f8(wf.succ[u][v]))
    return WorkflowFingerprint(
        digest=h.hexdigest(),
        n=wf.n,
        n_edges=wf.n_edges,
        work_hist=_log_hist(wf.work),
        mem_hist=_log_hist(wf.mem),
    )


def platform_signature(platform: Platform) -> str:
    """Digest of everything about ``platform`` that planning sees:
    (speed, memory) per processor in index order, the uniform β, and
    per-link overrides.  Name-independent (see module docstring)."""
    h = hashlib.sha256()
    h.update(b"repro-plat-1\x00")
    h.update(_i8(platform.k))
    h.update(_f8(platform.bandwidth))
    for p in platform.procs:
        h.update(_f8(p.speed))
        h.update(_f8(p.memory))
    for (a, b), bw in sorted(platform.link_bandwidth.items()):
        h.update(_i8(a))
        h.update(_i8(b))
        h.update(_f8(bw))
    return h.hexdigest()
