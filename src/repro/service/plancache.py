"""The plan cache: fingerprint → previously computed partition.

A cache entry stores the *winning partition* (``block_of_task``) and
winning k' of a completed plan, keyed on the pair (workflow digest,
platform signature) — see :mod:`repro.service.fingerprint`.  A hit
replays that partition through :meth:`Scheduler.seeded
<repro.core.scheduler.Scheduler.seeded>`: no k' sweep, Step 2 re-prices
the seed on the actual platform, Steps 3–4 repair and refine.  On the
same platform the seeded pipeline reproduces the cached plan's quality
(the k'-sweep winner's own refinement is a fixpoint), so the hit buys
roughly a sweep-length× planning-latency reduction at no makespan
premium; a *stale* seed (platform drifted, entry keyed elsewhere)
simply cannot occur because the platform signature is part of the key.

Eviction is LRU with a bounded capacity — the service's traffic model
is many users × few pipelines, so the working set is small and recency
is the right signal.  Hits/misses/stores are counted through
:mod:`repro.core.counters` (``service_cache_hits`` /
``service_cache_misses`` / ``service_cache_stores``) and surface in
``ServiceReport.cache_stats``.  Counters never influence control flow.

The cache also persists: :meth:`PlanCache.save` writes the whole store
(keys, partitions, LRU order) as JSON and :meth:`PlanCache.load` brings
it back, so a service restart — or a benchmark's warm phase — starts
with yesterday's working set instead of a cold sweep per fingerprint.
"""
from __future__ import annotations

import hashlib
import json
from collections import OrderedDict
from dataclasses import dataclass
from pathlib import Path

from repro.core import counters
from repro.core.platform import Platform

from .fingerprint import WorkflowFingerprint, platform_signature

__all__ = ["CachedPlan", "PlanCache"]


@dataclass
class CachedPlan:
    """One cached planning outcome (a partition, not a full mapping —
    processor assignment is always recomputed on the live platform)."""

    block_of_task: list[int]
    k_prime: int | None
    makespan: float     # as planned when stored (diagnostic only)
    hits: int = 0


class PlanCache:
    """Bounded LRU: (workflow digest, platform signature) → plan."""

    def __init__(self, capacity: int = 128) -> None:
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._store: "OrderedDict[str, CachedPlan]" = OrderedDict()
        self._evictions = 0

    def __len__(self) -> int:
        return len(self._store)

    # -------------------------------------------------------------- #
    @staticmethod
    def key(fp: WorkflowFingerprint, platform: Platform) -> str:
        h = hashlib.sha256()
        h.update(b"repro-plan-1\x00")
        h.update(fp.digest.encode("ascii"))
        h.update(platform_signature(platform).encode("ascii"))
        return h.hexdigest()

    def get(self, key: str) -> CachedPlan | None:
        """Look up ``key``; counts a hit or a miss either way."""
        plan = self._store.get(key)
        if plan is None:
            counters.bump("service_cache_misses")
            return None
        counters.bump("service_cache_hits")
        plan.hits += 1
        self._store.move_to_end(key)
        return plan

    def put(self, key: str, block_of_task: list[int],
            k_prime: int | None, makespan: float) -> None:
        self._store[key] = CachedPlan(
            block_of_task=[int(b) for b in block_of_task],
            k_prime=k_prime, makespan=float(makespan))
        self._store.move_to_end(key)
        counters.bump("service_cache_stores")
        while len(self._store) > self.capacity:
            self._store.popitem(last=False)
            self._evictions += 1

    def stats(self) -> dict:
        return {
            "size": len(self._store),
            "capacity": self.capacity,
            "evictions": self._evictions,
            "hits": sum(p.hits for p in self._store.values()),
        }

    # persistence --------------------------------------------------- #
    def save(self, path) -> None:
        """Write the cache to ``path`` as JSON, LRU order preserved
        (first entry = least recently used, evicted first on reload
        into a smaller cache)."""
        payload = {
            "version": 1,
            "capacity": self.capacity,
            "entries": [
                {
                    "key": key,
                    "block_of_task": list(plan.block_of_task),
                    "k_prime": plan.k_prime,
                    "makespan": plan.makespan,
                    "hits": plan.hits,
                }
                for key, plan in self._store.items()
            ],
        }
        Path(path).write_text(json.dumps(payload, indent=1))

    @classmethod
    def load(cls, path, capacity: int | None = None) -> "PlanCache":
        """Rebuild a cache from :meth:`save` output.  ``capacity``
        overrides the saved bound (excess entries evict LRU-first);
        loading counts neither hits nor stores."""
        payload = json.loads(Path(path).read_text())
        version = payload.get("version")
        if version != 1:
            raise ValueError(
                f"unsupported plan-cache file version {version!r}")
        cache = cls(capacity if capacity is not None
                    else int(payload["capacity"]))
        for e in payload["entries"]:
            cache._store[e["key"]] = CachedPlan(
                block_of_task=[int(b) for b in e["block_of_task"]],
                k_prime=(int(e["k_prime"])
                         if e["k_prime"] is not None else None),
                makespan=float(e["makespan"]),
                hits=int(e.get("hits", 0)),
            )
            while len(cache._store) > cache.capacity:
                cache._store.popitem(last=False)
                cache._evictions += 1
        return cache
