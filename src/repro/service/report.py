"""Service outcomes: per-job records, the deterministic trace, the report.

The split mirrors the determinism contract: a :class:`ServiceTrace` is
the pure **virtual-time** record of a run — per-job lifecycle times,
outcomes, mappings, the platform-utilization timeline, the event/log
stream — and round-trips through JSON bit-identically for the same
submission trace, whatever the wall clock or worker count did.  The
:class:`ServiceReport` wraps the trace together with the
*non-deterministic* observability: wall-clock planning latencies per
path (cold / seeded / replan) and the :mod:`repro.core.counters` delta
(``cache_stats``).  Tests compare traces; benchmarks read reports.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field

__all__ = ["JobRecord", "ServiceReport", "ServiceTrace"]

_TERMINAL = ("completed", "infeasible", "rejected")


@dataclass
class JobRecord:
    """One submission's full lifecycle, in virtual time.

    ``status`` is terminal and exclusive: ``"completed"``,
    ``"infeasible"`` (admitted, but no feasible plan even with the
    platform to itself — carries the structured ``infeasibility``
    dict), or ``"rejected"`` (never admitted — carries the
    ``rejection`` dict).  ``planning_path`` is ``"cold"`` or
    ``"seeded"`` (plan-cache hit); ``mapping`` is the final mapping
    summary (wall-clock ``runtime_s`` scrubbed to keep the trace
    deterministic).  ``makespan`` spans dispatch → finish and includes
    any mid-run replan stitches; ``queue_wait`` spans arrival →
    dispatch.
    """

    job_id: int
    name: str
    tenant: str
    arrival_t: float
    status: str
    deadline: float | None = None
    n_tasks: int | None = None
    fingerprint: str | None = None
    dispatch_t: float | None = None
    finish_t: float | None = None
    queue_wait: float | None = None
    latency: float | None = None
    makespan: float | None = None
    deadline_met: bool | None = None
    planning_path: str | None = None
    k_prime: int | None = None
    n_replans: int = 0
    n_deferrals: int = 0
    allocation: list[str] = field(default_factory=list)
    mapping: dict | None = None
    rejection: dict | None = None
    infeasibility: dict | None = None

    def __post_init__(self) -> None:
        if self.status not in _TERMINAL:
            raise ValueError(
                f"status must be one of {_TERMINAL}, got {self.status!r}")

    def to_dict(self) -> dict:
        return {
            "job_id": self.job_id, "name": self.name,
            "tenant": self.tenant, "arrival_t": self.arrival_t,
            "status": self.status, "deadline": self.deadline,
            "n_tasks": self.n_tasks, "fingerprint": self.fingerprint,
            "dispatch_t": self.dispatch_t, "finish_t": self.finish_t,
            "queue_wait": self.queue_wait, "latency": self.latency,
            "makespan": self.makespan,
            "deadline_met": self.deadline_met,
            "planning_path": self.planning_path,
            "k_prime": self.k_prime,
            "n_replans": self.n_replans,
            "n_deferrals": self.n_deferrals,
            "allocation": list(self.allocation),
            "mapping": self.mapping,
            "rejection": self.rejection,
            "infeasibility": self.infeasibility,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "JobRecord":
        return cls(**{k: d.get(k) for k in (
            "job_id", "name", "tenant", "arrival_t", "status",
            "deadline", "n_tasks", "fingerprint", "dispatch_t",
            "finish_t", "queue_wait", "latency", "makespan",
            "deadline_met", "planning_path", "k_prime",
            "mapping", "rejection", "infeasibility",
        )} | {
            "n_replans": int(d.get("n_replans", 0)),
            "n_deferrals": int(d.get("n_deferrals", 0)),
            "allocation": list(d.get("allocation", [])),
        })


@dataclass
class ServiceTrace:
    """Deterministic virtual-time record of one service run.

    ``log`` is the chronological service log (admit / reject / defer /
    dispatch / event / replan / complete entries, each a plain dict
    with ``t`` and ``kind``); ``utilization`` is the busy-processor
    timeline as ``[t, busy, k]`` change points; ``horizon`` is the last
    virtual instant anything happened.
    """

    name: str
    platform_name: str
    n_procs: int
    jobs: list[JobRecord] = field(default_factory=list)
    events: list[dict] = field(default_factory=list)
    log: list[dict] = field(default_factory=list)
    utilization: list[list] = field(default_factory=list)
    horizon: float = 0.0
    busy_proc_time: float = 0.0

    # -------------------------------------------------------------- #
    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "platform_name": self.platform_name,
            "n_procs": self.n_procs,
            "jobs": [j.to_dict() for j in self.jobs],
            "events": list(self.events),
            "log": list(self.log),
            "utilization": [list(u) for u in self.utilization],
            "horizon": self.horizon,
            "busy_proc_time": self.busy_proc_time,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "ServiceTrace":
        return cls(
            name=d["name"], platform_name=d["platform_name"],
            n_procs=int(d["n_procs"]),
            jobs=[JobRecord.from_dict(j) for j in d.get("jobs", [])],
            events=list(d.get("events", [])),
            log=list(d.get("log", [])),
            utilization=[list(u) for u in d.get("utilization", [])],
            horizon=float(d.get("horizon", 0.0)),
            busy_proc_time=float(d.get("busy_proc_time", 0.0)),
        )

    def to_json(self, *, indent: int | None = None) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_json(cls, s: str) -> "ServiceTrace":
        return cls.from_dict(json.loads(s))


@dataclass
class ServiceReport:
    """Trace + wall-clock observability for one service run.

    ``metrics`` is the run's non-counter metrics block
    (``{"gauges": ..., "histograms": ...}``, sparse — see
    :mod:`repro.obs.metrics`): the ``service_plan_latency_s``,
    ``service_queue_wait`` and ``service_makespan_premium`` histograms
    live here, and the percentile properties below derive from them.
    ``spans`` carries the run's finished tracer spans when
    ``ServiceConfig.obs`` enabled tracing (live objects — excluded
    from JSON and equality); ``pipelined`` likewise carries the live
    :class:`~repro.throughput.pipeline.PipelinedReport` of a
    :func:`~repro.service.sustained.run_sustained` replay.
    """

    trace: ServiceTrace
    cache_stats: dict = field(default_factory=dict)
    plan_wall_s: dict = field(default_factory=dict)  # path -> [seconds]
    total_time_s: float = 0.0
    metrics: dict = field(default_factory=dict)
    spans: list = field(default_factory=list, repr=False, compare=False)
    pipelined: object | None = field(default=None, repr=False,
                                     compare=False)

    # convenience views ------------------------------------------------ #
    @property
    def jobs(self) -> list[JobRecord]:
        return self.trace.jobs

    def by_status(self, status: str) -> list[JobRecord]:
        return [j for j in self.trace.jobs if j.status == status]

    @property
    def completed(self) -> list[JobRecord]:
        return self.by_status("completed")

    @property
    def rejected(self) -> list[JobRecord]:
        return self.by_status("rejected")

    @property
    def infeasible(self) -> list[JobRecord]:
        return self.by_status("infeasible")

    @property
    def cache_hit_rate(self) -> float | None:
        hits = self.cache_stats.get("service_cache_hits", 0)
        misses = self.cache_stats.get("service_cache_misses", 0)
        if hits + misses == 0:
            return None
        return hits / (hits + misses)

    @property
    def utilization(self) -> float | None:
        """Mean fraction of the platform busy over the horizon."""
        tr = self.trace
        if tr.horizon <= 0 or tr.n_procs == 0:
            return None
        return tr.busy_proc_time / (tr.horizon * tr.n_procs)

    # histogram-derived percentiles ------------------------------------ #
    def _hist_percentiles(self, name: str) -> dict | None:
        from repro.obs.metrics import percentiles

        return percentiles(
            self.metrics.get("histograms", {}).get(name, {}))

    @property
    def plan_latency_percentiles(self) -> dict | None:
        """``{"p50": ..., "p95": ..., "p99": ...}`` of wall-clock
        planning latency (seconds, all paths), or ``None``."""
        return self._hist_percentiles("service_plan_latency_s")

    @property
    def queue_wait_percentiles(self) -> dict | None:
        """p50/p95/p99 of virtual-time arrival→dispatch wait."""
        return self._hist_percentiles("service_queue_wait")

    @property
    def makespan_premium_percentiles(self) -> dict | None:
        """p50/p95/p99 of the seeded-plan makespan premium (ratio vs
        the cached winner; ``None`` without plan-cache hits)."""
        return self._hist_percentiles("service_makespan_premium")

    # sustained-stream views (run_sustained) --------------------------- #
    @property
    def instance_latency_percentiles(self) -> dict | None:
        """p50/p95/p99 of per-instance arrival→finish latency in a
        sustained run (virtual time), or ``None``."""
        return self._hist_percentiles("sustained_instance_latency")

    @property
    def instances_per_s(self) -> float | None:
        """Achieved throughput of a sustained run (instances per
        virtual time unit), or ``None``."""
        return self.metrics.get("gauges", {}).get(
            "sustained_instances_per_s")

    @property
    def saturation_rate(self) -> float | None:
        """The plan's analytic sustainable rate — offered rates beyond
        it saturate the pipeline; ``None`` outside sustained runs."""
        return self.metrics.get("gauges", {}).get(
            "sustained_saturation_rate")

    # serialization ---------------------------------------------------- #
    def to_dict(self) -> dict:
        return {
            "trace": self.trace.to_dict(),
            "cache_stats": dict(self.cache_stats),
            "plan_wall_s": {k: list(v)
                            for k, v in self.plan_wall_s.items()},
            "total_time_s": self.total_time_s,
            "metrics": dict(self.metrics),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "ServiceReport":
        return cls(
            trace=ServiceTrace.from_dict(d["trace"]),
            cache_stats=dict(d.get("cache_stats", {})),
            plan_wall_s={k: list(v)
                         for k, v in d.get("plan_wall_s", {}).items()},
            total_time_s=float(d.get("total_time_s", 0.0)),
            # absent on pre-PR-8 payloads: default to empty
            metrics=dict(d.get("metrics", {})),
        )

    def to_json(self, *, indent: int | None = None) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_json(cls, s: str) -> "ServiceReport":
        return cls.from_dict(json.loads(s))

    # stitched job-level Gantt ---------------------------------------- #
    def gantt(self, width: int = 64) -> str:
        """ASCII job timeline: ``·`` queued, ``█`` running, ``✕``
        infeasible end; a header row marks platform events ``▼``.

        One row per admitted job (rejected submissions are listed
        below the chart), stitched across replans — the job-level
        view of the whole multi-workflow run.
        """
        tr = self.trace
        horizon = tr.horizon if tr.horizon > 0 else 1.0
        scale = (width - 1) / horizon

        def col(t: float) -> int:
            return max(0, min(width - 1, int(t * scale)))

        lines = []
        marks = [" "] * width
        for e in tr.events:
            marks[col(float(e["time"]))] = "▼"
        label_w = max([12] + [len(f"{j.name}#{j.job_id}")
                              for j in tr.jobs])
        lines.append(f"{'':{label_w}}  |{''.join(marks)}|  t_max="
                     f"{tr.horizon:.1f}")
        for j in tr.jobs:
            if j.status == "rejected":
                continue
            row = [" "] * width
            start = col(j.arrival_t)
            end_t = (j.finish_t if j.finish_t is not None
                     else tr.horizon)
            disp = col(j.dispatch_t if j.dispatch_t is not None
                       else end_t)
            for c in range(start, disp):
                row[c] = "·"
            for c in range(disp, col(end_t) + 1):
                row[c] = "█"
            if j.status == "infeasible":
                row[col(end_t)] = "✕"
            tag = f"{j.name}#{j.job_id}"
            suffix = (f"  [{j.tenant}] {j.status}"
                      + (f" ({j.planning_path})"
                         if j.planning_path else ""))
            lines.append(f"{tag:{label_w}}  |{''.join(row)}|{suffix}")
        for j in tr.jobs:
            if j.status == "rejected":
                code = (j.rejection or {}).get("code", "?")
                lines.append(
                    f"{j.name}#{j.job_id}: rejected [{code}] "
                    f"at t={j.arrival_t:g}")
        return "\n".join(lines)
