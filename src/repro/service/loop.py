"""The service event loop: continuous multi-workflow operation.

One deterministic **virtual-time** queue drives everything: workflow
submissions, job completions and :class:`PlatformEvent` groups are
heap-ordered by ``(time, priority, push-sequence)`` with platform
events first (capacity changes are visible before anything else at the
same instant), completions second (freed processors are visible to
same-instant submissions) and submissions last.  Processing an item
never consults a wall clock, so the same submission trace yields a
bit-identical :class:`~repro.service.report.ServiceTrace` — including
under ``workers > 1`` (the parallel k' sweep is bit-identical by
construction).

Job lifecycle::

    submitted ── admit ──> queued ── dispatch ──> running ── complete
        │ (validation /         │ (weighted fair     │  ▲
        │  quota violation)     │  share; deferral   │  └ event →
        ▼                       │  is transient)     │    pause/freeze/
    rejected                    ▼                    ▼    warm replan
                           infeasible ◀────── displaced (requeued)

Co-scheduling: a dispatched job *owns* exactly the processors its
mapping uses; everything else stays free for the next job in fair
order.  Ownership is tracked in global indices, while each job plans
and executes in its own carved sub-platform's coordinates — the
``to_global`` map ties them together across events (which compact
global indices).  When an event touches a job's processors, the job is
paused at the event instant (:func:`repro.scenario.freeze_prefix` on
its own sub-platform — the PR-4 checkpoint machinery), its durable
prefix is frozen, and the residual warm-starts on the surviving owned
processors via :meth:`Scheduler.resume`; if the warm path fails, a
cold replan on survivors-plus-free capacity; if even that fails with
other jobs still running, the job is *displaced* back into the queue
(its residual re-fingerprinted, retried as capacity frees); only a job
that cannot be planned with the whole platform free is terminally
infeasible — structured, never an exception.

Planning goes through the plan cache: a fingerprint hit seeds the
partition (:meth:`Scheduler.seeded` — no k' sweep), a miss plans cold
and stores the winner.  The identity anchor: one submission at t=0, no
events, empty quotas reproduces ``schedule(wf, platform,
simulate=True)`` bit-exactly — the cold path *is* that call.
"""
from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field, replace
from heapq import heappop, heappush
from typing import Sequence

from repro.core import counters
from repro.core.dag import Workflow
from repro.core.platform import Platform
from repro.core.scheduler import Scheduler, SchedulerConfig
from repro.core.workflows import WorkflowValidationError
from repro.obs import (
    JsonlSink,
    ObsConfig,
    service_virtual_events,
    span_events,
    write_chrome_trace,
)
from repro.obs import tracer as _trc
from repro.obs.metrics import METRICS, RATIO_BOUNDARIES
from repro.obs.tracer import trace_span
from repro.scenario import (
    LinkDegrade,
    PlatformEvent,
    ProcArrival,
    ProcFailure,
    SpeedChange,
    freeze_prefix,
    validate_event_timeline,
)

from .admission import FairQueue, QuotaConfig
from .fingerprint import fingerprint_workflow, platform_signature
from .plancache import PlanCache
from .report import JobRecord, ServiceReport, ServiceTrace
from .submission import Rejection, Submission, resolve_workflow

__all__ = ["ServiceConfig", "WorkflowService", "run_service"]

_PRIO_EVENT = 0
_PRIO_COMPLETE = 1
_PRIO_SUBMIT = 2


@dataclass
class ServiceConfig:
    """Knobs for one service run.

    ``scheduler`` drives every planning call (cold, seeded, warm —
    ``simulate`` is forced on internally: execution *is* the
    simulation).  ``plan_cache=False`` disables fingerprint seeding;
    ``cache_capacity`` bounds the LRU.  Quotas default to the empty
    config (admit everything, plain FIFO fairness).  ``obs`` is the
    run's :class:`~repro.obs.ObsConfig`: ``enabled`` traces the event
    loop (submission → admission → dispatch → replan → completion,
    with the scheduler's own spans nested under each planning call),
    ``sink`` streams the service log + spans as JSONL, ``trace_path``
    writes a Chrome trace at the end of the run that unifies the
    wall-clock span tracks with the virtual-time job/utilization
    tracks (separate clock-domain ``pid``\\ s).  All of it is inert:
    the :class:`ServiceTrace` is bit-identical with ``obs`` on or off.
    """

    scheduler: SchedulerConfig = field(default_factory=SchedulerConfig)
    quotas: QuotaConfig = field(default_factory=QuotaConfig)
    plan_cache: bool = True
    cache_capacity: int = 128
    name: str = "service"
    obs: ObsConfig | None = None


class _Job:
    """Internal mutable job state (the public view is JobRecord)."""

    def __init__(self, seq: int, sub: Submission) -> None:
        self.seq = seq
        self.sub = sub
        self.name = sub.name
        self.tenant = sub.tenant
        self.arrival_t = sub.arrival_t
        self.deadline = sub.deadline
        self.status = "submitted"
        self.wf: Workflow | None = None       # current (residual) DAG
        self.n_tasks: int | None = None       # as admitted
        self.fp = None                        # fingerprint of self.wf
        self.dispatch_t: float | None = None
        self.finish_t: float | None = None
        self.planning_path: str | None = None
        self.k_prime: int | None = None
        self.n_replans = 0
        self.n_deferrals = 0
        self.gen = 0                          # completion generation
        self.platform: Platform | None = None  # carved planning frame
        self.to_global: list[int | None] = []  # carve idx -> global idx
        self.allocation: set[int] = set()      # owned global indices
        self.alloc_names: list[str] = []
        self.mapping = None                    # MappingResult (live)
        self.sim = None                        # SimReport of the segment
        self.summary = None                    # MappingSummary (last plan)
        self.t_seg = 0.0                       # segment start (virtual)
        self.rejection: Rejection | None = None
        self.infeasibility = None
        self._skip_sig: str | None = None      # last infeasible carve sig
        self._last_defer: tuple | None = None


class WorkflowService:
    """Deterministic virtual-time scheduler-as-a-service.

    Build one with the submission trace, the shared platform and the
    (time-sorted) platform-event timeline, then :meth:`run` it to a
    :class:`~repro.service.report.ServiceReport`.  Pass a
    :class:`~repro.service.plancache.PlanCache` to share cached plans
    across runs (e.g. warm-vs-cold benchmarking); by default each run
    gets a fresh cache.
    """

    def __init__(
        self,
        submissions: Sequence[Submission],
        platform: Platform,
        events: Sequence[PlatformEvent] = (),
        config: ServiceConfig | None = None,
        cache: PlanCache | None = None,
    ) -> None:
        self.config = config if config is not None else ServiceConfig()
        validate_event_timeline(tuple(events))
        self.events = tuple(events)
        self.platform = platform
        self._home_platform = platform
        self.jobs: list[_Job] = [
            _Job(i, s) for i, s in enumerate(
                sorted(submissions, key=lambda s: s.arrival_t))
        ]
        self.cache = cache if cache is not None else (
            PlanCache(self.config.cache_capacity)
            if self.config.plan_cache else None)
        self.queue = FairQueue(self.config.quotas)
        self._running: list[_Job] = []
        self._heap: list = []
        self._push_ctr = itertools.count()
        self._log: list[dict] = []
        self._event_dicts: list[dict] = []
        self._util: list[list] = []
        self._busy_time = 0.0
        self._last_t = 0.0
        self._last_busy = 0
        self._horizon = 0.0
        self._plan_wall: dict[str, list[float]] = {}
        self._sched_cfg = replace(self.config.scheduler, simulate=True)
        self._sink = JsonlSink(None)  # run() opens the real one

    # ---------------------------------------------------------------- #
    # bookkeeping helpers
    # ---------------------------------------------------------------- #
    def _note(self, entry: dict) -> None:
        """Append to the deterministic service log and stream the same
        entry to the JSONL sink (no-op sink when obs is off)."""
        self._log.append(entry)
        self._sink.emit({"event": "service", **entry})

    def _push(self, t: float, prio: int, kind: str, payload) -> None:
        heappush(self._heap, (t, prio, next(self._push_ctr), kind,
                              payload))

    def _free(self) -> list[int]:
        busy: set[int] = set()
        for job in self._running:
            busy |= job.allocation
        return [j for j in range(self.platform.k) if j not in busy]

    def _advance(self, t: float) -> None:
        if t > self._last_t:
            self._busy_time += (t - self._last_t) * self._last_busy
            self._last_t = t
        self._horizon = max(self._horizon, t)

    def _note_util(self, t: float) -> None:
        self._advance(t)
        busy = sum(len(j.allocation) for j in self._running)
        k = self.platform.k
        if (not self._util or self._util[-1][1] != busy
                or self._util[-1][2] != k):
            if self._util and self._util[-1][0] == t:
                self._util[-1] = [t, busy, k]
            else:
                self._util.append([t, busy, k])
        self._last_busy = busy

    def _comm(self):
        return (self._sched_cfg.sim_options or {}).get(
            "comm", "contention-free")

    def _carve(self, procs: list[int]) -> tuple[Platform, list[int]]:
        """Sub-platform over global indices ``procs`` (sorted).  The
        full set returns the platform object itself — ``without``
        would rename it, and the single-job anchor must plan on the
        *identical* platform ``schedule()`` would see."""
        procs = sorted(procs)
        if len(procs) == self.platform.k:
            return self.platform, list(range(self.platform.k))
        drop = set(range(self.platform.k)) - set(procs)
        return self.platform.without(drop), procs

    # ---------------------------------------------------------------- #
    # admission
    # ---------------------------------------------------------------- #
    def _reject(self, job: _Job, t: float, code: str,
                reason: str) -> None:
        job.status = "rejected"
        job.finish_t = t
        job.rejection = Rejection(time=t, job_id=job.seq, name=job.name,
                                  tenant=job.tenant, code=code,
                                  reason=reason)
        counters.bump("service_rejections")
        self._note({"t": t, "kind": "reject", "job": job.seq,
                          "code": code, "reason": reason})

    def _admit(self, job: _Job, t: float) -> None:
        with trace_span("service.admit", job=job.seq, t=t):
            self._admit_impl(job, t)

    def _admit_impl(self, job: _Job, t: float) -> None:
        try:
            wf = resolve_workflow(job.sub)
        except WorkflowValidationError as exc:
            self._reject(job, t, "malformed", str(exc))
            return
        quota = self.config.quotas.quota(job.tenant)
        if quota.max_tasks is not None and wf.n > quota.max_tasks:
            self._reject(
                job, t, "size-quota",
                f"{wf.n} tasks exceeds tenant cap {quota.max_tasks}")
            return
        if (quota.max_pending is not None
                and self.queue.pending(job.tenant) >= quota.max_pending):
            self._reject(
                job, t, "queue-quota",
                f"tenant already has {self.queue.pending(job.tenant)} "
                f"pending job(s) (cap {quota.max_pending})")
            return
        job.wf = wf
        job.n_tasks = wf.n
        job.fp = fingerprint_workflow(wf)
        job.status = "queued"
        self.queue.push(job)
        counters.bump("service_admissions")
        self._note({"t": t, "kind": "admit", "job": job.seq,
                          "tenant": job.tenant, "n_tasks": wf.n,
                          "fingerprint": job.fp.digest[:12]})

    # ---------------------------------------------------------------- #
    # planning (plan cache in front of the scheduler)
    # ---------------------------------------------------------------- #
    def _wall(self, path: str, t0: float) -> None:
        """Record one planning call's wall clock under ``path`` and in
        the ``service_plan_latency_s`` histogram (p50/p95/p99 on the
        report derive from it)."""
        dt = time.perf_counter() - t0
        self._plan_wall.setdefault(path, []).append(dt)
        METRICS.observe("service_plan_latency_s", dt)

    def _plan(self, job: _Job, sub_plat: Platform):
        """Returns ``(report, path)`` with ``path`` in
        {"seeded", "cold"}; wall clocks land in ``plan_wall_s``."""
        tr = _trc.current_tracer()
        if tr is None:
            return self._plan_impl(job, sub_plat)
        snap = counters.snapshot()
        with tr.span("service.plan", job=job.seq,
                     n_tasks=job.wf.n) as sp:
            rep, path = self._plan_impl(job, sub_plat)
            # the span carries the planning call's counter deltas
            sp.attrs.update(counters.delta(snap))
            sp.attrs["path"] = path
            sp.attrs["feasible"] = rep.feasible
        return rep, path

    def _plan_impl(self, job: _Job, sub_plat: Platform):
        sch = Scheduler(self._sched_cfg)
        key = None
        if self.cache is not None:
            key = PlanCache.key(job.fp, sub_plat)
            cached = self.cache.get(key)
            if cached is not None:
                t0 = time.perf_counter()
                rep = sch.seeded(job.wf, sub_plat,
                                 cached.block_of_task,
                                 k_prime=cached.k_prime)
                self._wall("seeded", t0)
                if rep.feasible:
                    if cached.makespan:
                        # premium the seeded plan pays over its cached
                        # winner (≈1.0 when the seed held up)
                        METRICS.observe(
                            "service_makespan_premium",
                            rep.summary.makespan / cached.makespan,
                            boundaries=RATIO_BOUNDARIES)
                    return rep, "seeded"
                counters.bump("service_seed_fallbacks")
        t0 = time.perf_counter()
        rep = sch.schedule(job.wf, sub_plat)
        self._wall("cold", t0)
        if rep.feasible and key is not None:
            self.cache.put(key, rep.summary.block_of_task,
                           rep.summary.k_prime, rep.summary.makespan)
        return rep, "cold"

    # ---------------------------------------------------------------- #
    # dispatch
    # ---------------------------------------------------------------- #
    def _running_count(self, tenant: str) -> int:
        return sum(1 for j in self._running if j.tenant == tenant)

    def _defer(self, job: _Job, t: float, code: str,
               reason: str) -> None:
        key = (code, reason)
        if job._last_defer == key:
            return  # same verdict as last attempt: don't re-log
        job._last_defer = key
        job.n_deferrals += 1
        counters.bump("service_deferrals")
        self._note({"t": t, "kind": "defer", "job": job.seq,
                          "code": code, "reason": reason})

    def _fail(self, job: _Job, t: float, infeas) -> None:
        if job.status == "queued":
            self.queue.remove(job)
        elif job in self._running:
            self._running.remove(job)
        job.status = "infeasible"
        job.finish_t = t
        job.infeasibility = infeas
        job.allocation = set()
        counters.bump("service_infeasible")
        self._note({"t": t, "kind": "infeasible", "job": job.seq,
                          "stage": infeas.stage, "reason": infeas.reason})
        self._note_util(t)

    def _start(self, job: _Job, rep, path: str, t: float,
               sub_plat: Platform, to_global: list[int]) -> None:
        res, sim = rep.best, rep.sim
        q = res.quotient
        used = sorted({q.proc[v] for v in q.members})
        job.platform = sub_plat
        job.to_global = list(to_global)
        job.allocation = {to_global[pj] for pj in used}
        job.alloc_names = sorted(
            self.platform.procs[g].name for g in job.allocation)
        job.mapping = res
        job.sim = sim
        job.summary = rep.summary
        job.status = "running"
        if job.dispatch_t is None:        # displaced jobs keep the first
            job.dispatch_t = t
            job.planning_path = path
            job.k_prime = rep.summary.k_prime
            # virtual-time wait from arrival to first dispatch
            METRICS.observe("service_queue_wait", t - job.arrival_t)
        job.t_seg = t
        job.gen += 1
        job._skip_sig = None
        job._last_defer = None
        self.queue.remove(job)
        self.queue.charge(job.tenant, job.wf.total_work())
        self._running.append(job)
        self._push(t + sim.makespan, _PRIO_COMPLETE, "complete",
                   (job, job.gen))
        counters.bump("service_dispatches")
        self._note({
            "t": t, "kind": "dispatch", "job": job.seq, "path": path,
            "procs": len(job.allocation), "makespan": sim.makespan,
        })
        self._note_util(t)

    def _dispatch(self, t: float) -> None:
        with trace_span("service.dispatch", t=t):
            self._dispatch_impl(t)

    def _dispatch_impl(self, t: float) -> None:
        while True:
            free = self._free()
            if not free or not len(self.queue):
                return
            placed = False
            for job in self.queue.fair_order():
                quota = self.config.quotas.quota(job.tenant)
                if (quota.max_running is not None
                        and self._running_count(job.tenant)
                        >= quota.max_running):
                    self._defer(
                        job, t, "running-quota",
                        f"tenant at max_running={quota.max_running}")
                    continue
                sub_plat, to_global = self._carve(free)
                sig = platform_signature(sub_plat)
                if job._skip_sig == sig:
                    continue  # same capacity already proved infeasible
                rep, path = self._plan(job, sub_plat)
                if rep.feasible:
                    self._start(job, rep, path, t, sub_plat, to_global)
                    placed = True
                    break  # capacity + fair order changed: new round
                if self._running or len(free) < self.platform.k:
                    job._skip_sig = sig
                    self._defer(job, t, "capacity",
                                rep.infeasibility.reason)
                else:
                    # the whole platform was free and it still failed:
                    # no future capacity can be larger (arrivals reset
                    # _skip_sig via the new signature anyway)
                    self._fail(job, t, rep.infeasibility)
                    placed = True
                    break
            if not placed:
                return

    # ---------------------------------------------------------------- #
    # platform events
    # ---------------------------------------------------------------- #
    def _affected(self, ev: PlatformEvent,
                  alloc_cur: dict[_Job, set[int]]) -> set[_Job]:
        if isinstance(ev, ProcFailure):
            return {job for job, ac in alloc_cur.items()
                    if ac & ev.procs}
        if isinstance(ev, SpeedChange):
            return {job for job, ac in alloc_cur.items()
                    if ev.proc in ac}
        if isinstance(ev, LinkDegrade):
            return {job for job, ac in alloc_cur.items()
                    if ev.src in ac and ev.dst in ac}
        if isinstance(ev, ProcArrival):
            return set()  # new capacity disturbs nobody's plan
        # unknown event kind: conservatively replan everyone running
        return set(alloc_cur)

    def _on_events(self, group: Sequence[PlatformEvent],
                   t: float) -> None:
        cur = self.platform
        cmap: dict[int, int | None] = {j: j for j in range(cur.k)}
        affected: set[_Job] = set()
        for ev in group:
            alloc_cur = {
                job: {cmap[g] for g in job.allocation
                      if cmap[g] is not None}
                for job in self._running
            }
            affected |= self._affected(ev, alloc_cur)
            cur, m = ev.apply(cur)
            cmap = {j: (m[c] if c is not None else None)
                    for j, c in cmap.items()}
            self._event_dicts.append(ev.to_dict())
            self._note({"t": t, "kind": "event",
                              "event": ev.kind,
                              "detail": ev.describe()})
        self.platform = cur
        for job in self._running:
            job.allocation = {cmap[g] for g in job.allocation
                              if cmap[g] is not None}
            job.to_global = [
                cmap[g] if (g is not None and cmap[g] is not None)
                else None
                for g in job.to_global
            ]
        for job in sorted(affected, key=lambda j: j.seq):
            self._replan_job(job, t)
        self._note_util(t)
        self._dispatch(t)

    def _requeue(self, job: _Job, t: float, residual: Workflow) -> None:
        """Displace: back to the queue with the residual workflow."""
        job.status = "queued"
        job.wf = residual
        job.fp = fingerprint_workflow(residual)
        job.mapping = job.sim = None
        job.allocation = set()
        job.platform = None
        job.to_global = []
        job._skip_sig = None
        job._last_defer = None
        self.queue.push(job)
        counters.bump("service_displacements")
        self._note({"t": t, "kind": "displaced", "job": job.seq,
                          "residual_tasks": residual.n})

    def _adopt(self, job: _Job, rep, t: float, path: str) -> None:
        """Install a feasible replan as the job's new segment."""
        res, sim = rep.best, rep.sim
        q = res.quotient
        used = sorted({q.proc[v] for v in q.members})
        job.mapping = res
        job.sim = sim
        job.summary = rep.summary
        job.allocation = {job.to_global[pj] for pj in used}
        job.alloc_names = sorted(
            self.platform.procs[g].name for g in job.allocation)
        job.t_seg = t
        job.gen += 1
        self._push(t + sim.makespan, _PRIO_COMPLETE, "complete",
                   (job, job.gen))
        entry = {
            "t": t, "kind": "replan", "job": job.seq, "path": path,
            "procs": len(job.allocation),
            "residual_tasks": job.wf.n,
            "remaining_makespan": sim.makespan,
        }
        ckpt = getattr(job, "_checkpoint_decisions", None)
        if ckpt:
            entry["checkpoint_priced"] = len(ckpt)
            entry["checkpoint_migrate_wins"] = sum(
                1 for c in ckpt if c["decision"] == "migrate")
        self._note(entry)

    def _replan_job(self, job: _Job, t: float) -> None:
        tr = _trc.current_tracer()
        if tr is None:
            return self._replan_job_impl(job, t)
        snap = counters.snapshot()
        with tr.span("service.replan", job=job.seq, t=t) as sp:
            self._replan_job_impl(job, t)
            sp.attrs.update(counters.delta(snap))
            sp.attrs["status"] = job.status

    def _replan_job_impl(self, job: _Job, t: float) -> None:
        rel = t - job.t_seg
        if rel >= job.sim.horizon:
            return  # segment already (durably) done; completion stands
        counters.bump("service_replans")
        job.n_replans += 1
        old_carve, to_global = job.platform, job.to_global
        # carve procs still owned by this job after the event remap
        surv = [cj for cj in range(old_carve.k)
                if to_global[cj] is not None
                and to_global[cj] in job.allocation]
        if surv:
            # re-carve from the *current* global platform so the warm
            # start sees post-event speeds/links, not the stale copies
            # held by the old carve (the pause itself, below, runs on
            # the old carve: the prefix executed under the old state)
            new_carve, new_to_global = self._carve(
                [to_global[cj] for cj in surv])
            pos = {g: i for i, g in enumerate(new_to_global)}
            carve_map = {
                cj: (pos[to_global[cj]] if cj in set(surv) else None)
                for cj in range(old_carve.k)}
        else:
            new_carve, new_to_global = Platform(
                [], self.platform.bandwidth,
                f"{old_carve.name}-degraded"), []
            carve_map = {cj: None for cj in range(old_carve.k)}
        fz = freeze_prefix(job.wf, job.mapping, old_carve, rel,
                           new_carve, carve_map, comm=self._comm())
        # restart-vs-migrate pricing for the replan log entry
        job._checkpoint_decisions = fz.checkpoint_decisions
        if fz.state.wf.n == 0:
            return  # nothing left to run; completion event stands
        warm = None
        if surv:
            t0 = time.perf_counter()
            warm = Scheduler(self._sched_cfg).resume(fz.state)
            self._wall("replan", t0)
        if warm is not None and warm.feasible:
            job.wf = fz.state.wf
            job.platform = new_carve
            job.to_global = list(new_to_global)
            self._adopt(job, warm, t, "warm")
            return
        # warm path gone (all procs lost, or residual no longer fits):
        # cold replan on surviving owned + currently free capacity
        counters.bump("service_replan_cold_fallbacks")
        cand = sorted(set(job.allocation) | set(self._free()))
        if cand:
            plat2, to_g2 = self._carve(cand)
            t0 = time.perf_counter()
            cold = Scheduler(self._sched_cfg).schedule(fz.state.wf,
                                                      plat2)
            self._wall("replan", t0)
            if cold.feasible:
                job.wf = fz.state.wf
                job.platform = plat2
                job.to_global = list(to_g2)
                self._adopt(job, cold, t, "cold")
                return
            if len(cand) == self.platform.k:
                # had the whole platform and still failed: terminal
                self._fail(job, t, cold.infeasibility)
                return
        # capacity is tied up elsewhere: displace, retry as it frees
        self._running.remove(job)
        self._requeue(job, t, fz.state.wf)
        self._note_util(t)

    # ---------------------------------------------------------------- #
    # completion
    # ---------------------------------------------------------------- #
    def _on_complete(self, payload, t: float) -> None:
        job, gen = payload
        if job.status != "running" or gen != job.gen:
            return  # superseded by a replan or displacement
        with trace_span("service.complete", job=job.seq, t=t):
            self._complete_impl(job, t)

    def _complete_impl(self, job: _Job, t: float) -> None:
        job.status = "completed"
        job.finish_t = t
        self._running.remove(job)
        job.allocation = set()
        counters.bump("service_completions")
        self._note({"t": t, "kind": "complete", "job": job.seq,
                          "tenant": job.tenant})
        self._note_util(t)
        self._dispatch(t)

    # ---------------------------------------------------------------- #
    def _record(self, job: _Job) -> JobRecord:
        mapping = None
        if job.summary is not None and job.status == "completed":
            mapping = job.summary.to_dict()
            mapping["runtime_s"] = 0.0   # wall clock: not trace material
        queue_wait = latency = makespan = deadline_met = None
        if job.dispatch_t is not None:
            queue_wait = job.dispatch_t - job.arrival_t
        if job.finish_t is not None and job.status != "rejected":
            latency = job.finish_t - job.arrival_t
        if job.status == "completed":
            makespan = job.finish_t - job.dispatch_t
            if job.deadline is not None:
                deadline_met = job.finish_t <= job.deadline
        return JobRecord(
            job_id=job.seq, name=job.name, tenant=job.tenant,
            arrival_t=job.arrival_t, status=job.status,
            deadline=job.deadline, n_tasks=job.n_tasks,
            fingerprint=job.fp.digest if job.fp is not None else None,
            dispatch_t=job.dispatch_t, finish_t=job.finish_t,
            queue_wait=queue_wait, latency=latency, makespan=makespan,
            deadline_met=deadline_met,
            planning_path=job.planning_path, k_prime=job.k_prime,
            n_replans=job.n_replans, n_deferrals=job.n_deferrals,
            allocation=list(job.alloc_names),
            mapping=mapping,
            rejection=(job.rejection.to_dict()
                       if job.rejection is not None else None),
            infeasibility=(job.infeasibility.to_dict()
                           if job.infeasibility is not None else None),
        )

    def run(self) -> ServiceReport:
        """Drain the virtual-time queue; always a ServiceReport."""
        obs = self.config.obs
        tracer = obs.make_tracer() if obs is not None else None
        self._sink = JsonlSink(obs.sink if obs is not None else None)
        try:
            # activate(None) is a passthrough: an enclosing tracer (a
            # caller tracing across service runs) keeps collecting
            with _trc.activate(tracer):
                report = self._run_impl()
            if tracer is not None:
                for s in tracer.spans:
                    self._sink.emit({"event": "span", **s.to_dict()})
        finally:
            self._sink.close()
            self._sink = JsonlSink(None)
        if tracer is not None:
            report.spans = list(tracer.spans)
            if obs.trace_path is not None:
                # one file, two clock domains: wall-clock spans under
                # pid "wall", virtual-time job/util tracks under
                # pid "virtual"
                write_chrome_trace(
                    obs.trace_path,
                    span_events(tracer.spans)
                    + service_virtual_events(report.trace),
                    meta={"service": self.config.name})
        return report

    def _run_impl(self) -> ServiceReport:
        t_wall = time.perf_counter()
        msnap = METRICS.snapshot()
        snap = msnap["counters"]
        for job in self.jobs:
            self._push(job.arrival_t, _PRIO_SUBMIT, "submit", job)
        group: list[PlatformEvent] = []
        for ev in self.events:   # validated sorted; group equal times
            if group and group[0].time == ev.time:
                group.append(ev)
            else:
                if group:
                    self._push(group[0].time, _PRIO_EVENT, "events",
                               group)
                group = [ev]
        if group:
            self._push(group[0].time, _PRIO_EVENT, "events", group)

        while self._heap:
            t, _prio, _c, kind, payload = heappop(self._heap)
            self._advance(t)
            if kind == "events":
                self._on_events(payload, t)
            elif kind == "complete":
                self._on_complete(payload, t)
            else:
                self._admit(payload, t)
                self._dispatch(t)

        leftovers = [j.seq for j in self.jobs
                     if j.status not in ("completed", "infeasible",
                                         "rejected")]
        if leftovers:  # conservation invariant; should be unreachable
            raise RuntimeError(
                f"service loop drained with non-terminal job(s) "
                f"{leftovers}")

        cache_stats = counters.delta(snap)
        if self.cache is not None:
            cache_stats["service_plan_cache_size"] = len(self.cache)
        mdelta = METRICS.delta(msnap)
        mdelta.pop("counters", None)  # already surfaced as cache_stats
        trace = ServiceTrace(
            name=self.config.name,
            platform_name=self._home_platform.name,
            n_procs=self._home_platform.k,
            jobs=[self._record(j) for j in self.jobs],
            events=list(self._event_dicts),
            log=list(self._log),
            utilization=[list(u) for u in self._util],
            horizon=self._horizon,
            busy_proc_time=self._busy_time,
        )
        return ServiceReport(
            trace=trace,
            cache_stats=cache_stats,
            plan_wall_s={k: list(v)
                         for k, v in sorted(self._plan_wall.items())},
            total_time_s=time.perf_counter() - t_wall,
            metrics=mdelta,
        )


def run_service(
    submissions: Sequence[Submission],
    platform: Platform,
    events: Sequence[PlatformEvent] = (),
    config: ServiceConfig | None = None,
    *,
    cache: PlanCache | None = None,
    obs: ObsConfig | None = None,
) -> ServiceReport:
    """One-call convenience: build a :class:`WorkflowService`, run it.

    ``obs`` overrides ``config.obs`` (shortcut for tracing one run:
    ``run_service(subs, plat, obs=ObsConfig(enabled=True,
    trace_path="trace.json"))``).
    """
    if obs is not None:
        config = replace(config if config is not None
                         else ServiceConfig(), obs=obs)
    return WorkflowService(submissions, platform, events, config,
                           cache).run()
