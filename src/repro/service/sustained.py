"""Sustained admission: an arrival stream of one fingerprinted workflow.

:func:`run_sustained` is the service-level face of
:mod:`repro.throughput`: it admits ``n_instances`` repeat arrivals of
the *same* workflow, plans once through the plan cache (a fingerprint
hit replays the cached partition through the ``throughput_seeded``
pipeline — no k' sweep; a miss runs the full rate-maximizing sweep of
:func:`~repro.throughput.plan.plan_throughput` and stores the winner),
replicates the mapping onto idle processors, and replays the whole
stream in one pipelined engine pass.  The outcome is an ordinary
:class:`~repro.service.report.ServiceReport`: one completed
:class:`~repro.service.report.JobRecord` per instance, achieved
instances/s and the analytic saturation rate as gauges, and the
per-instance latency distribution as a histogram — so p50/p99 come off
the same :mod:`repro.obs.metrics` machinery every other report uses
(``report.instance_latency_percentiles``).

Determinism matches the rest of the service: arrival instants are
seeded (:class:`~repro.throughput.arrivals.ArrivalSpec`), the engine is
virtual-time, and the trace is bit-identical run to run.  At rate→0
(one instance) the pipelined replay reproduces ``schedule(wf, platform,
simulate=True)`` bit-exactly — the same identity anchor the event loop
holds.
"""
from __future__ import annotations

import time

from repro.core import counters
from repro.core.dag import Workflow
from repro.core.platform import Platform
from repro.core.scheduler import (
    PIPELINES,
    Scheduler,
    SchedulerConfig,
)
from repro.obs.metrics import METRICS

from .fingerprint import fingerprint_workflow
from .plancache import PlanCache
from .report import JobRecord, ServiceReport, ServiceTrace

__all__ = ["run_sustained"]


def _throughput_opts(latency_bound, max_replicas, include_comm) -> dict:
    opts = {"include_comm": include_comm}
    if latency_bound is not None:
        opts["latency_bound"] = latency_bound
    if max_replicas is not None:
        opts["max_replicas"] = max_replicas
    return opts


def run_sustained(
    workflow: Workflow,
    platform: Platform,
    *,
    rate: float,
    n_instances: int = 32,
    arrival_kind: str = "poisson",
    seed: int = 0,
    latency_bound: float | None = None,
    max_replicas: int | None = None,
    include_comm: bool = True,
    comm: str = "contention-free",
    config: SchedulerConfig | None = None,
    cache: PlanCache | None = None,
    name: str = "sustained",
    **overrides,
) -> ServiceReport:
    """Admit a sustained arrival stream of ``workflow`` at ``rate``.

    Plans through ``cache`` when given (fingerprint hit → seeded
    throughput pipeline, miss → full rate-maximizing k' sweep, winner
    stored), replicates onto idle processors, replays ``n_instances``
    seeded arrivals in one pipelined simulation with summed memory
    occupancy, and lands everything on a
    :class:`~repro.service.report.ServiceReport`:

    * one completed :class:`JobRecord` per instance (arrival /
      dispatch / finish in virtual time, the replica group's processor
      names as the allocation);
    * ``sustained_instance_latency`` histogram →
      ``report.instance_latency_percentiles``;
    * gauges ``sustained_instances_per_s`` (achieved),
      ``sustained_offered_rate``, ``sustained_saturation_rate`` (the
      plan's analytic sustainable rate — offers beyond it saturate);
    * the live :class:`~repro.throughput.pipeline.PipelinedReport` as
      ``report.pipelined`` (memory-occupancy trace included).

    An unplannable workflow (or a ``latency_bound`` no k' meets) is a
    structured outcome: a report whose single job is ``infeasible``.
    Extra ``overrides`` (``kprime``, ``workers``, ...) are
    :class:`~repro.core.scheduler.SchedulerConfig` material for the
    cold planning path; a cache hit skips the sweep they shape.
    """
    from repro.throughput import ArrivalSpec, plan_throughput, \
        simulate_pipelined

    t_wall = time.perf_counter()
    msnap = METRICS.snapshot()
    csnap = msnap["counters"]
    plan_wall: dict[str, list[float]] = {}
    log: list[dict] = []
    opts = _throughput_opts(latency_bound, max_replicas, include_comm)
    cfg = config if config is not None else SchedulerConfig()

    fp = fingerprint_workflow(workflow)
    key = PlanCache.key(fp, platform) if cache is not None else None

    best = plan = k_prime = None
    infeasibility = None
    path = "cold"
    if cache is not None:
        cached = cache.get(key)
        if cached is not None:
            t0 = time.perf_counter()
            rep = Scheduler(
                cfg, stages=PIPELINES["throughput_seeded"],
                throughput_options=opts,
            ).seeded(workflow, platform, cached.block_of_task,
                     k_prime=cached.k_prime)
            dt = time.perf_counter() - t0
            plan_wall.setdefault("seeded", []).append(dt)
            METRICS.observe("service_plan_latency_s", dt)
            if rep.feasible:
                best = rep.best
                plan = best.extras.get("throughput")
                k_prime = cached.k_prime
                path = "seeded"
            else:
                counters.bump("service_seed_fallbacks")
    if plan is None:
        t0 = time.perf_counter()
        tr = plan_throughput(
            workflow, platform, latency_bound=latency_bound,
            max_replicas=max_replicas, include_comm=include_comm,
            config=cfg, **overrides)
        dt = time.perf_counter() - t0
        plan_wall.setdefault("cold", []).append(dt)
        METRICS.observe("service_plan_latency_s", dt)
        path = "cold"
        if tr.feasible:
            best, plan, k_prime = tr.best, tr.plan, tr.k_prime
            if cache is not None:
                cache.put(key, best.block_of_task(), k_prime,
                          best.makespan)
        else:
            infeasibility = tr.report.infeasibility

    jobs: list[JobRecord] = []
    horizon = 0.0
    busy = 0.0
    pipelined = None
    if plan is None:
        log.append({"t": 0.0, "kind": "infeasible",
                    "reason": (infeasibility.reason
                               if infeasibility is not None else "?")})
        jobs.append(JobRecord(
            job_id=0, name=name, tenant="stream", arrival_t=0.0,
            status="infeasible", n_tasks=workflow.n,
            fingerprint=fp.digest,
            infeasibility=(infeasibility.to_dict()
                           if infeasibility is not None else None),
        ))
    else:
        spec = ArrivalSpec(float(rate), arrival_kind)
        pipelined = simulate_pipelined(
            best, platform, arrivals=spec.times(n_instances, seed),
            plan=plan, comm=comm, memory=True)
        horizon = pipelined.horizon
        busy = sum(
            pipelined.block_finish[v] - pipelined.block_start[v]
            for v in pipelined.block_start)
        log.append({
            "t": 0.0, "kind": "plan", "path": path, "k_prime": k_prime,
            "replicas": plan.n_replicas, "plan_rate": plan.rate,
            "period": plan.period,
        })
        group_names = [
            sorted(platform.procs[r].name for r in g.procs)
            for g in plan.groups
        ]
        for rec in pipelined.instances:
            METRICS.observe("sustained_instance_latency", rec.latency)
            jobs.append(JobRecord(
                job_id=rec.instance, name=f"{name}#{rec.instance}",
                tenant="stream", arrival_t=rec.arrival,
                status="completed", n_tasks=workflow.n,
                fingerprint=fp.digest, dispatch_t=rec.start,
                finish_t=rec.finish,
                queue_wait=rec.start - rec.arrival,
                latency=rec.latency,
                makespan=rec.finish - rec.start,
                planning_path=path, k_prime=k_prime,
                allocation=list(group_names[rec.replica]),
            ))
            log.append({"t": rec.arrival, "kind": "instance",
                        "instance": rec.instance,
                        "group": rec.replica})
        if not pipelined.memory.feasible:
            for viol in pipelined.memory.violations:
                log.append({
                    "t": viol.time, "kind": "memory_violation",
                    "proc": viol.proc, "instance": viol.instance,
                    "occupancy": viol.occupancy,
                    "capacity": viol.capacity,
                })
        gauges = {
            "sustained_instances_per_s": pipelined.achieved_rate,
            "sustained_offered_rate": float(rate),
            "sustained_saturation_rate": plan.rate,
            "sustained_replicas": float(plan.n_replicas),
        }
        for g, v in gauges.items():
            METRICS.gauge(g, v)

    cache_stats = counters.delta(csnap)
    if cache is not None:
        cache_stats["service_plan_cache_size"] = len(cache)
    mdelta = METRICS.delta(msnap)
    mdelta.pop("counters", None)
    if plan is not None:
        # METRICS.delta drops gauges whose value matches the opening
        # snapshot — a repeat run landing on the identical achieved
        # rate would silently lose them, so pin this run's gauges.
        mdelta.setdefault("gauges", {}).update(gauges)
    trace = ServiceTrace(
        name=name,
        platform_name=platform.name,
        n_procs=platform.k,
        jobs=jobs,
        events=[],
        log=log,
        utilization=[],
        horizon=horizon,
        busy_proc_time=busy,
    )
    return ServiceReport(
        trace=trace,
        cache_stats=cache_stats,
        plan_wall_s={k: list(v) for k, v in sorted(plan_wall.items())},
        total_time_s=time.perf_counter() - t_wall,
        metrics=mdelta,
        pipelined=pipelined,
    )
