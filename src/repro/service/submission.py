"""What enters the service: submissions, and their structured fates.

A :class:`Submission` is one unit of demand: a workflow (live
:class:`~repro.core.dag.Workflow`, JSON text, or a parsed JSON dict —
the latter two model untrusted wire input), the tenant it belongs to,
its virtual arrival time, and an optional deadline.  Submission
*metadata* is validated eagerly (the driver building the trace is
trusted code, so a bad ``arrival_t`` raises); the workflow *payload* is
validated lazily at admission via :func:`resolve_workflow`, so a
malformed body becomes a structured :class:`Rejection` — never an
exception out of the event loop, in the spirit of
:class:`~repro.core.scheduler.Infeasibility`.

:class:`Rejection` is terminal (the job never entered the queue);
:class:`Deferral` is transient (the job stays queued and is retried
whenever capacity changes) and appears in the service log, not as a
job outcome.
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass

from repro.core.dag import Workflow
from repro.core.workflows import WorkflowValidationError, from_json

__all__ = ["Deferral", "Rejection", "Submission", "resolve_workflow"]


@dataclass
class Submission:
    """One workflow arriving at ``arrival_t`` on behalf of ``tenant``."""

    workflow: Workflow | str | dict
    tenant: str = "default"
    arrival_t: float = 0.0
    deadline: float | None = None
    name: str = ""

    def __post_init__(self) -> None:
        if not (math.isfinite(self.arrival_t) and self.arrival_t >= 0):
            raise ValueError(
                f"arrival_t must be finite and >= 0, "
                f"got {self.arrival_t!r}")
        if self.deadline is not None and not (
                math.isfinite(self.deadline)
                and self.deadline >= self.arrival_t):
            raise ValueError(
                f"deadline must be finite and >= arrival_t, "
                f"got {self.deadline!r}")
        if not self.name:
            if isinstance(self.workflow, Workflow):
                self.name = self.workflow.name
            else:
                self.name = "submission"


def resolve_workflow(sub: Submission) -> Workflow:
    """Materialize the submission's workflow, validating untrusted
    payloads (raises :class:`WorkflowValidationError` — the admission
    path turns that into a :class:`Rejection`)."""
    payload = sub.workflow
    if isinstance(payload, Workflow):
        return payload
    if isinstance(payload, dict):
        payload = json.dumps(payload)
    if isinstance(payload, str):
        return from_json(payload)
    raise WorkflowValidationError(
        "bad-schema",
        f"workflow payload must be a Workflow, JSON text or dict, "
        f"got {type(payload).__name__}")


@dataclass
class Rejection:
    """Terminal: the submission never entered the admission queue.

    ``code`` is stable and machine-readable: ``"malformed"`` (payload
    failed validation), ``"size-quota"`` (more tasks than the tenant's
    ``max_tasks``), ``"queue-quota"`` (tenant's ``max_pending``
    exceeded).
    """

    time: float
    job_id: int
    name: str
    tenant: str
    code: str
    reason: str

    def to_dict(self) -> dict:
        return {
            "time": self.time, "job_id": self.job_id,
            "name": self.name, "tenant": self.tenant,
            "code": self.code, "reason": self.reason,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "Rejection":
        return cls(time=float(d["time"]), job_id=int(d["job_id"]),
                   name=str(d["name"]), tenant=str(d["tenant"]),
                   code=str(d["code"]), reason=str(d["reason"]))


@dataclass
class Deferral:
    """Transient: an admitted job could not be dispatched right now.

    ``code``: ``"capacity"`` (no feasible plan on the currently free
    processors — retried when capacity changes) or ``"running-quota"``
    (tenant already at ``max_running``).  Deferrals are log entries,
    never job outcomes.
    """

    time: float
    job_id: int
    name: str
    tenant: str
    code: str
    reason: str

    def to_dict(self) -> dict:
        return {
            "time": self.time, "job_id": self.job_id,
            "name": self.name, "tenant": self.tenant,
            "code": self.code, "reason": self.reason,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "Deferral":
        return cls(time=float(d["time"]), job_id=int(d["job_id"]),
                   name=str(d["name"]), tenant=str(d["tenant"]),
                   code=str(d["code"]), reason=str(d["reason"]))
